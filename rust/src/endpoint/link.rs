//! The service⇄agent link (the paper's ZeroMQ channel between a
//! forwarder and its funcX agent), as typed in-process channels with
//! explicit liveness so tests can inject disconnections (§4.1 fault
//! tolerance).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::common::task::{Task, TaskResult};

/// Message from the forwarder down to the agent.
#[derive(Debug)]
pub enum Downstream {
    Tasks(Vec<Task>),
    /// Forwarder-initiated liveness probe.
    Ping,
    /// Orderly shutdown.
    Shutdown,
}

/// Message from the agent up to the forwarder.
#[derive(Debug)]
pub enum Upstream {
    Results(Vec<TaskResult>),
    /// Periodic heartbeat (§4.1: 30 s default, configurable).
    Heartbeat { active_workers: usize, pending_tasks: usize },
}

/// One side's endpoints of the duplex link.
pub struct ForwarderSide {
    pub tx: Sender<Downstream>,
    pub rx: Receiver<Upstream>,
    alive: Arc<AtomicBool>,
}

pub struct AgentSide {
    pub tx: Sender<Upstream>,
    pub rx: Receiver<Downstream>,
    alive: Arc<AtomicBool>,
}

/// Create a connected duplex link.
pub fn link() -> (ForwarderSide, AgentSide) {
    let (dtx, drx) = channel();
    let (utx, urx) = channel();
    let alive = Arc::new(AtomicBool::new(true));
    (
        ForwarderSide { tx: dtx, rx: urx, alive: alive.clone() },
        AgentSide { tx: utx, rx: drx, alive },
    )
}

impl ForwarderSide {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// Simulate a network partition / agent crash (tests, §4.1).
    pub fn sever(&self) {
        self.alive.store(false, Ordering::Relaxed);
    }

    pub fn send(&self, msg: Downstream) -> bool {
        self.is_alive() && self.tx.send(msg).is_ok()
    }

    pub fn try_recv(&self) -> Option<Upstream> {
        if !self.is_alive() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(m) => Some(m),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.sever();
                None
            }
        }
    }
}

impl AgentSide {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub fn sever(&self) {
        self.alive.store(false, Ordering::Relaxed);
    }

    pub fn send(&self, msg: Upstream) -> bool {
        self.is_alive() && self.tx.send(msg).is_ok()
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Downstream> {
        if !self.is_alive() {
            return None;
        }
        match self.rx.recv_timeout(d) {
            Ok(m) => Some(m),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                self.sever();
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::*;
    use crate::common::task::Payload;
    use crate::serialize::Buffer;

    fn mk_task() -> Task {
        Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Noop,
            Buffer::empty(),
        )
    }

    #[test]
    fn duplex_roundtrip() {
        let (f, a) = link();
        assert!(f.send(Downstream::Tasks(vec![mk_task()])));
        match a.recv_timeout(Duration::from_millis(100)) {
            Some(Downstream::Tasks(ts)) => assert_eq!(ts.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
        assert!(a.send(Upstream::Heartbeat { active_workers: 4, pending_tasks: 0 }));
        assert!(matches!(f.try_recv(), Some(Upstream::Heartbeat { .. })));
    }

    #[test]
    fn severed_link_drops_messages() {
        let (f, a) = link();
        f.sever();
        assert!(!f.send(Downstream::Ping));
        assert!(!a.is_alive() || !f.is_alive());
        assert!(!a.send(Upstream::Results(vec![])));
    }

    #[test]
    fn dropped_agent_detected() {
        let (f, a) = link();
        drop(a);
        assert!(f.try_recv().is_none());
        assert!(!f.is_alive(), "disconnect should sever the link");
    }
}
