//! The service⇄agent link (the paper's ZeroMQ channel between a
//! forwarder and its funcX agent), as typed in-process channels with
//! explicit liveness so tests can inject disconnections (§4.1 fault
//! tolerance).
//!
//! Each side carries a wakeup latch ([`Notify`]) signalled whenever the
//! *peer* sends a message (and when the link is severed), so the
//! forwarder and agent loops can block on "anything happened on my link"
//! — multiplexed with other wake sources through the same handle —
//! instead of sleep-polling.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use crate::common::sync::Notify;
use crate::common::task::{Task, TaskResult};
use crate::datastore::TieredStore;

/// Message from the forwarder down to the agent.
///
/// Tasks travel as `Arc<Task>` handles: the forwarder's in-flight ack
/// cache, the link frame, and the manager queue all share one `Task`
/// allocation (whose `input` is itself a view into the queue frame) —
/// no payload bytes are copied between submit-side serialization and
/// the worker.
pub enum Downstream {
    Tasks(Vec<Arc<Task>>),
    /// A service payload store, advertised on connect so the endpoint's
    /// fabric auto-peers for `iref` resolution (no manual
    /// `connect_peer` wiring). A sharded service plane sends one of
    /// these per shard store; the agent needs no shard awareness
    /// because each store is keyed by its own owner id.
    Advertise(Arc<TieredStore>),
    /// Forwarder-initiated liveness probe.
    Ping,
    /// Orderly shutdown.
    Shutdown,
    /// Orderly *retirement* (§4.1 churn): the agent stops accepting
    /// work, drains its managers, flushes buffered results, answers
    /// with [`Upstream::Deregister`], and exits — the forwarder then
    /// runs the service-side decommission (frame drain, store
    /// withdrawal, fabric disconnect, spool GC).
    Decommission,
}

impl std::fmt::Debug for Downstream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Downstream::Tasks(ts) => f.debug_tuple("Tasks").field(&ts.len()).finish(),
            Downstream::Advertise(s) => f.debug_tuple("Advertise").field(&s.owner()).finish(),
            Downstream::Ping => f.write_str("Ping"),
            Downstream::Shutdown => f.write_str("Shutdown"),
            Downstream::Decommission => f.write_str("Decommission"),
        }
    }
}

/// Message from the agent up to the forwarder.
pub enum Upstream {
    Results(Vec<TaskResult>),
    /// The endpoint's tiered store, advertised on agent start so the
    /// service fabric auto-peers for `rref` resolution (§5 result
    /// offload — no manual `connect_peer` wiring). The service wires
    /// this store into EVERY shard's fabric, so a task on any shard can
    /// resolve refs owned by this endpoint.
    Advertise(Arc<TieredStore>),
    /// Periodic heartbeat (§4.1: 30 s default, configurable).
    Heartbeat { active_workers: usize, pending_tasks: usize },
    /// Final message of a decommissioned agent: everything it was going
    /// to send has been sent (results flushed, managers drained) and it
    /// is exiting for good — the forwarder may retire the endpoint.
    Deregister,
}

impl std::fmt::Debug for Upstream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Upstream::Results(rs) => f.debug_tuple("Results").field(&rs.len()).finish(),
            Upstream::Advertise(s) => f.debug_tuple("Advertise").field(&s.owner()).finish(),
            Upstream::Heartbeat { active_workers, pending_tasks } => f
                .debug_struct("Heartbeat")
                .field("active_workers", active_workers)
                .field("pending_tasks", pending_tasks)
                .finish(),
            Upstream::Deregister => f.write_str("Deregister"),
        }
    }
}

/// One side's endpoints of the duplex link.
pub struct ForwarderSide {
    pub tx: Sender<Downstream>,
    pub rx: Receiver<Upstream>,
    alive: Arc<AtomicBool>,
    /// Signalled when the agent sends upstream or the link dies.
    wake: Arc<Notify>,
    /// The agent side's latch; we signal it on every downstream send.
    peer_wake: Arc<Notify>,
}

pub struct AgentSide {
    pub tx: Sender<Upstream>,
    pub rx: Receiver<Downstream>,
    alive: Arc<AtomicBool>,
    /// Signalled when the forwarder sends downstream or the link dies.
    wake: Arc<Notify>,
    /// The forwarder side's latch; we signal it on every upstream send.
    peer_wake: Arc<Notify>,
}

/// Create a connected duplex link.
pub fn link() -> (ForwarderSide, AgentSide) {
    let (dtx, drx) = channel();
    let (utx, urx) = channel();
    let alive = Arc::new(AtomicBool::new(true));
    let fwd_wake = Arc::new(Notify::new());
    let agent_wake = Arc::new(Notify::new());
    (
        ForwarderSide {
            tx: dtx,
            rx: urx,
            alive: alive.clone(),
            wake: fwd_wake.clone(),
            peer_wake: agent_wake.clone(),
        },
        AgentSide { tx: utx, rx: drx, alive, wake: agent_wake, peer_wake: fwd_wake },
    )
}

impl ForwarderSide {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// Simulate a network partition / agent crash (tests, §4.1). Wakes
    /// both sides so blocked loops notice promptly.
    pub fn sever(&self) {
        self.alive.store(false, Ordering::Relaxed);
        self.wake.notify();
        self.peer_wake.notify();
    }

    /// This side's wakeup latch: signalled on upstream traffic and link
    /// death. Attach it to other sources (e.g. a queue watch) to block
    /// on all of them at once.
    pub fn wake_handle(&self) -> Arc<Notify> {
        self.wake.clone()
    }

    pub fn send(&self, msg: Downstream) -> bool {
        let ok = self.is_alive() && self.tx.send(msg).is_ok();
        if ok {
            self.peer_wake.notify();
        }
        ok
    }

    pub fn try_recv(&self) -> Option<Upstream> {
        if !self.is_alive() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(m) => Some(m),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.sever();
                None
            }
        }
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Upstream> {
        if !self.is_alive() {
            return None;
        }
        match self.rx.recv_timeout(d) {
            Ok(m) => Some(m),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                self.sever();
                None
            }
        }
    }
}

impl AgentSide {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub fn sever(&self) {
        self.alive.store(false, Ordering::Relaxed);
        self.wake.notify();
        self.peer_wake.notify();
    }

    /// This side's wakeup latch: signalled on downstream traffic and
    /// link death (workers also signal it when results are ready).
    pub fn wake_handle(&self) -> Arc<Notify> {
        self.wake.clone()
    }

    pub fn send(&self, msg: Upstream) -> bool {
        let ok = self.is_alive() && self.tx.send(msg).is_ok();
        if ok {
            self.peer_wake.notify();
        }
        ok
    }

    pub fn try_recv(&self) -> Option<Downstream> {
        if !self.is_alive() {
            return None;
        }
        match self.rx.try_recv() {
            Ok(m) => Some(m),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.sever();
                None
            }
        }
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<Downstream> {
        if !self.is_alive() {
            return None;
        }
        match self.rx.recv_timeout(d) {
            Ok(m) => Some(m),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                self.sever();
                None
            }
        }
    }
}

// Dropping either side kills the link and wakes the peer, so a blocked
// event loop notices a vanished counterpart immediately instead of at
// its timeout bound.
impl Drop for ForwarderSide {
    fn drop(&mut self) {
        self.sever();
    }
}

impl Drop for AgentSide {
    fn drop(&mut self) {
        self.sever();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::*;
    use crate::common::task::Payload;
    use crate::serialize::Buffer;

    /// The zero-copy dispatch invariant at the link hop: the task the
    /// agent receives is the *same allocation* the forwarder retained in
    /// its in-flight cache — an Arc handoff, not a clone of the record
    /// (let alone its payload).
    #[test]
    fn tasks_cross_link_by_handle_not_copy() {
        let (f, a) = link();
        let task = Arc::new(mk_task());
        let in_flight = task.clone(); // forwarder ack-cache handle
        assert!(f.send(Downstream::Tasks(vec![task])));
        match a.recv_timeout(Duration::from_millis(100)) {
            Some(Downstream::Tasks(ts)) => {
                assert!(Arc::ptr_eq(&ts[0], &in_flight), "link must not copy tasks");
                // Two live handles: the ack cache and the received one.
                assert_eq!(Arc::strong_count(&in_flight), 2);
                assert!(ts[0].input.same_allocation(&in_flight.input));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    fn mk_task() -> Task {
        Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Noop,
            Buffer::empty(),
        )
    }

    #[test]
    fn duplex_roundtrip() {
        let (f, a) = link();
        assert!(f.send(Downstream::Tasks(vec![Arc::new(mk_task())])));
        match a.recv_timeout(Duration::from_millis(100)) {
            Some(Downstream::Tasks(ts)) => assert_eq!(ts.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
        assert!(a.send(Upstream::Heartbeat { active_workers: 4, pending_tasks: 0 }));
        assert!(matches!(f.try_recv(), Some(Upstream::Heartbeat { .. })));
    }

    #[test]
    fn sends_signal_peer_wake() {
        let (f, a) = link();
        let fw = f.wake_handle();
        let aw = a.wake_handle();
        let f_seen = fw.epoch();
        let a_seen = aw.epoch();
        assert!(f.send(Downstream::Ping));
        assert_ne!(aw.epoch(), a_seen, "downstream send wakes the agent");
        assert!(a.send(Upstream::Heartbeat { active_workers: 0, pending_tasks: 0 }));
        assert_ne!(fw.epoch(), f_seen, "upstream send wakes the forwarder");
        // Severing wakes both sides.
        let f_seen = fw.epoch();
        let a_seen = aw.epoch();
        f.sever();
        assert_ne!(fw.epoch(), f_seen);
        assert_ne!(aw.epoch(), a_seen);
    }

    #[test]
    fn severed_link_drops_messages() {
        let (f, a) = link();
        f.sever();
        assert!(!f.send(Downstream::Ping));
        assert!(!a.is_alive() || !f.is_alive());
        assert!(!a.send(Upstream::Results(vec![])));
    }

    #[test]
    fn dropped_agent_detected() {
        let (f, a) = link();
        drop(a);
        assert!(f.try_recv().is_none());
        assert!(!f.is_alive(), "disconnect should sever the link");
    }
}
