//! §4.3 — the funcX endpoint: agent → managers → workers.
//!
//! [`EndpointBuilder`] assembles a live endpoint (threads over in-process
//! links); the service's forwarder connects to it through
//! [`link::link`]. The discrete-event simulator mirrors this topology
//! under virtual time (see [`crate::sim`]).

pub mod agent;
pub mod link;
pub mod manager;

pub use agent::{AgentConfig, AgentHandle, AgentStats};
pub use link::{link, AgentSide, Downstream, ForwarderSide, Upstream};
pub use manager::{Manager, ManagerCtx};

use std::sync::Arc;

use crate::common::config::EndpointConfig;
use crate::common::time::{Clock, WallClock};
use crate::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
use crate::data::DataChannel;
use crate::datastore::DataFabric;
use crate::metrics::{FlightRecorder, LatencyBreakdown};
use crate::provider::{Provider, SimProvider};
use crate::routing::{Scheduler, WarmingAware};
use crate::runtime::{PayloadExecutor, PjrtRuntime, WorkerExecutor};

/// Builder for a live endpoint.
pub struct EndpointBuilder {
    cfg: EndpointConfig,
    system: SystemProfile,
    tech: ContainerTech,
    provider: Option<Box<dyn Provider>>,
    scheduler: Option<Box<dyn Scheduler>>,
    executor: Option<Arc<dyn WorkerExecutor>>,
    runtime: Option<Arc<PjrtRuntime>>,
    channel: Option<Arc<dyn DataChannel>>,
    fabric: Option<Arc<DataFabric>>,
    clock: Option<Arc<dyn Clock>>,
    latency: Option<Arc<LatencyBreakdown>>,
    recorder: Option<Arc<FlightRecorder>>,
    cold_start_scale: f64,
    heartbeat_period_s: f64,
    seed: u64,
}

impl Default for EndpointBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EndpointBuilder {
    pub fn new() -> Self {
        EndpointBuilder {
            cfg: EndpointConfig::default(),
            system: SystemProfile::Local,
            tech: ContainerTech::None,
            provider: None,
            scheduler: None,
            executor: None,
            runtime: None,
            channel: None,
            fabric: None,
            clock: None,
            latency: None,
            recorder: None,
            cold_start_scale: 0.001,
            heartbeat_period_s: 1.0,
            seed: 42,
        }
    }

    pub fn config(mut self, cfg: EndpointConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn profile(mut self, system: SystemProfile, tech: ContainerTech) -> Self {
        self.system = system;
        self.tech = tech;
        self
    }

    pub fn provider(mut self, p: Box<dyn Provider>) -> Self {
        self.provider = Some(p);
        self
    }

    pub fn scheduler(mut self, s: Box<dyn Scheduler>) -> Self {
        self.scheduler = Some(s);
        self
    }

    /// Override the worker backend (e.g. a
    /// [`crate::runtime::ProcessExecutor`] running tasks in forked
    /// worker children with measured start costs). Defaults to the
    /// in-process [`PayloadExecutor`] with modeled start costs.
    pub fn executor(mut self, e: Arc<dyn WorkerExecutor>) -> Self {
        self.executor = Some(e);
        self
    }

    /// Attach the PJRT runtime so workers can run artifact payloads.
    pub fn runtime(mut self, rt: Arc<PjrtRuntime>) -> Self {
        self.runtime = Some(rt);
        self
    }

    /// Attach an intra-endpoint data channel (§5.2).
    pub fn data_channel(mut self, ch: Arc<dyn DataChannel>) -> Self {
        self.channel = Some(ch);
        self
    }

    /// Attach the endpoint's data-fabric handle (§5): workers resolve
    /// by-ref task inputs through it. Peer it with the service store
    /// (and other endpoints) before starting the agent.
    pub fn fabric(mut self, f: Arc<DataFabric>) -> Self {
        self.fabric = Some(f);
        self
    }

    pub fn clock(mut self, c: Arc<dyn Clock>) -> Self {
        self.clock = Some(c);
        self
    }

    pub fn latency(mut self, l: Arc<LatencyBreakdown>) -> Self {
        self.latency = Some(l);
        self
    }

    /// Attach a shared flight recorder so this endpoint's agent,
    /// workers, fabric, and store append trace events into the same
    /// rings the service assembles from. Without one, tracing is a
    /// no-op at this endpoint.
    pub fn recorder(mut self, r: Arc<FlightRecorder>) -> Self {
        self.recorder = Some(r);
        self
    }

    /// Scale factor on sampled cold-start durations (1.0 = realistic).
    pub fn cold_start_scale(mut self, s: f64) -> Self {
        self.cold_start_scale = s;
        self
    }

    pub fn heartbeat_period(mut self, s: f64) -> Self {
        self.heartbeat_period_s = s;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Start the agent servicing `link`.
    pub fn start(self, link: AgentSide) -> AgentHandle {
        let clock = self.clock.unwrap_or_else(|| Arc::new(WallClock::new()));
        let latency = self.latency.unwrap_or_default();
        let recorder = self.recorder.unwrap_or_else(FlightRecorder::disabled);
        // Sink the recorder into the endpoint's fabric and store so
        // resolve/spill/shed events from worker-driven I/O land in the
        // same rings as the agent's dispatch events. First-call-wins:
        // a fabric already wired (e.g. to the service recorder) keeps
        // its original sink.
        if recorder.enabled() {
            if let Some(fabric) = &self.fabric {
                fabric.with_recorder(recorder.clone());
                fabric.local().with_recorder(recorder.clone(), clock.clone());
            }
        }
        let executor: Arc<dyn WorkerExecutor> = match self.executor {
            Some(e) => e,
            None => Arc::new(PayloadExecutor::new(self.runtime, self.channel)),
        };
        let config = AgentConfig {
            start_model: TABLE3_MODELS.lookup(self.system, self.tech),
            provider: self.provider.unwrap_or_else(|| Box::new(SimProvider::local(7))),
            scheduler: self.scheduler.unwrap_or_else(|| Box::new(WarmingAware::default())),
            executor,
            fabric: self.fabric,
            clock,
            latency,
            recorder,
            cold_start_scale: self.cold_start_scale,
            heartbeat_period_s: self.heartbeat_period_s,
            cfg: self.cfg,
            seed: self.seed,
        };
        AgentHandle::spawn(link, config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::*;
    use crate::common::task::{Payload, Task, TaskState};
    use crate::serialize::Buffer;
    use std::time::Duration;

    fn mk_task(payload: Payload) -> std::sync::Arc<Task> {
        std::sync::Arc::new(Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            payload,
            Buffer::empty(),
        ))
    }

    #[test]
    fn end_to_end_tasks_through_agent() {
        let (fwd, agent_side) = link::link();
        let cfg = EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() };
        let handle = EndpointBuilder::new().config(cfg).start(agent_side);

        fwd.send(Downstream::Tasks(vec![mk_task(Payload::Noop), mk_task(Payload::Noop)]));
        let mut results = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while results.len() < 2 && std::time::Instant::now() < deadline {
            if let Some(Upstream::Results(rs)) = fwd.recv_timeout(Duration::from_millis(100)) {
                results.extend(rs);
            }
        }
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.state == TaskState::Success));
        fwd.send(Downstream::Shutdown);
        handle.join();
    }

    #[test]
    fn elastic_scale_out_from_zero() {
        let (fwd, agent_side) = link::link();
        let cfg = EndpointConfig {
            min_nodes: 0,
            max_nodes: 2,
            workers_per_node: 2,
            strategy_period_s: 0.01,
            ..Default::default()
        };
        let handle = EndpointBuilder::new().config(cfg).start(agent_side);
        // No nodes initially; submitting tasks must trigger scale-out.
        fwd.send(Downstream::Tasks((0..4).map(|_| mk_task(Payload::Noop)).collect()));
        let mut got = 0;
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while got < 4 && std::time::Instant::now() < deadline {
            if let Some(Upstream::Results(rs)) = fwd.recv_timeout(Duration::from_millis(100)) {
                got += rs.len();
            }
        }
        assert_eq!(got, 4, "tasks must complete after elastic scale-out");
        assert!(handle.stats.nodes_provisioned.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        fwd.send(Downstream::Shutdown);
        handle.join();
    }

    #[test]
    fn heartbeats_flow() {
        let (fwd, agent_side) = link::link();
        let cfg = EndpointConfig { min_nodes: 1, ..Default::default() };
        let handle =
            EndpointBuilder::new().config(cfg).heartbeat_period(0.02).start(agent_side);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut beats = 0;
        while beats < 3 && std::time::Instant::now() < deadline {
            if let Some(Upstream::Heartbeat { .. }) = fwd.recv_timeout(Duration::from_millis(100))
            {
                beats += 1;
            }
        }
        assert!(beats >= 3, "agent must heartbeat periodically");
        fwd.send(Downstream::Shutdown);
        handle.join();
    }

    #[test]
    fn severed_link_stops_agent() {
        let (fwd, agent_side) = link::link();
        let cfg = EndpointConfig { min_nodes: 1, ..Default::default() };
        let handle = EndpointBuilder::new().config(cfg).start(agent_side);
        fwd.sever();
        drop(fwd);
        // join() must return (agent notices the dead link).
        handle.join();
    }
}
