//! §4.3 — the funcX agent: the persistent per-endpoint process that
//! queues tasks, provisions nodes through the provider, routes tasks to
//! managers (§6.2), drives the elastic strategy (§6.3), and heartbeats
//! to its forwarder (§4.1).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::common::config::EndpointConfig;
use crate::common::rng::Rng;
use crate::common::task::{Task, TaskResult};
use crate::common::time::{Clock, Time};
use crate::containers::StartCostModel;
use crate::endpoint::link::{AgentSide, Downstream, Upstream};
use crate::endpoint::manager::{Manager, ManagerCtx};
use crate::metrics::LatencyBreakdown;
use crate::provider::{NodeHandle, Provider, ScaleDecision, Strategy, StrategyInputs};
use crate::routing::Scheduler;
use crate::runtime::PayloadExecutor;

/// Shared, externally-readable agent statistics.
#[derive(Default)]
pub struct AgentStats {
    pub tasks_received: AtomicU64,
    pub tasks_dispatched: AtomicU64,
    pub results_returned: AtomicU64,
    pub cold_starts: AtomicU64,
    pub warm_hits: AtomicU64,
    pub nodes_provisioned: AtomicU64,
    pub nodes_released: AtomicU64,
    pub heartbeats_sent: AtomicU64,
}

/// Everything the agent needs at spawn time.
pub struct AgentConfig {
    pub cfg: EndpointConfig,
    pub provider: Box<dyn Provider>,
    pub scheduler: Box<dyn Scheduler>,
    pub executor: Arc<PayloadExecutor>,
    pub clock: Arc<dyn Clock>,
    pub latency: Arc<LatencyBreakdown>,
    pub start_model: StartCostModel,
    pub cold_start_scale: f64,
    pub heartbeat_period_s: f64,
    pub seed: u64,
}

/// Handle to a running agent thread.
pub struct AgentHandle {
    pub stats: Arc<AgentStats>,
    thread: Option<JoinHandle<()>>,
}

impl AgentHandle {
    /// Spawn the agent loop servicing `link`.
    pub fn spawn(link: AgentSide, config: AgentConfig) -> Self {
        let stats = Arc::new(AgentStats::default());
        let st = stats.clone();
        let thread = std::thread::Builder::new()
            .name("funcx-agent".into())
            .spawn(move || agent_loop(link, config, st))
            .expect("spawn agent");
        AgentHandle { stats, thread: Some(thread) }
    }

    /// Wait for the agent to exit (after a Shutdown message or severed
    /// link).
    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

struct NodeSlot {
    manager: Manager,
    idle_since: Option<Time>,
}

fn agent_loop(link: AgentSide, mut config: AgentConfig, stats: Arc<AgentStats>) {
    let mut pending: VecDeque<Task> = VecDeque::new();
    let mut nodes: HashMap<NodeHandle, NodeSlot> = HashMap::new();
    let (result_tx, result_rx): (Sender<TaskResult>, Receiver<TaskResult>) = channel();
    let strategy = Strategy::new(config.cfg.clone());
    let mut rng = Rng::new(config.seed);
    let mut last_strategy_tick: Time = f64::NEG_INFINITY;
    let mut last_heartbeat: Time = f64::NEG_INFINITY;

    // Pre-provision the configured minimum.
    if config.cfg.min_nodes > 0 {
        let now = config.clock.now();
        config.provider.request_nodes(config.cfg.min_nodes, now);
        stats.nodes_provisioned.fetch_add(config.cfg.min_nodes as u64, Ordering::Relaxed);
    }

    loop {
        let now = config.clock.now();

        // 1. Intake from the forwarder.
        match link.recv_timeout(Duration::from_millis(2)) {
            Some(Downstream::Tasks(ts)) => {
                stats.tasks_received.fetch_add(ts.len() as u64, Ordering::Relaxed);
                pending.extend(ts);
            }
            Some(Downstream::Ping) => {}
            Some(Downstream::Shutdown) => break,
            None => {
                if !link.is_alive() {
                    break;
                }
            }
        }

        // 2. Activate nodes that cleared the provider queue.
        for h in config.provider.poll(now) {
            let ctx = ManagerCtx {
                executor: config.executor.clone(),
                results: result_tx.clone(),
                clock: config.clock.clone(),
                latency: config.latency.clone(),
                start_model: config.start_model,
                cold_start_scale: config.cold_start_scale,
            };
            let m = Manager::spawn(
                config.cfg.workers_per_node,
                config.cfg.container_idle_timeout_s,
                ctx,
                rng.next_u64(),
            );
            nodes.insert(h, NodeSlot { manager: m, idle_since: None });
        }

        // 3. Route pending tasks to managers (§6.2).
        if !pending.is_empty() && !nodes.is_empty() {
            let handles: Vec<NodeHandle> = nodes.keys().copied().collect();
            let mut views: Vec<crate::routing::ManagerView> =
                handles.iter().map(|h| nodes[h].manager.view()).collect();
            let by_id: HashMap<crate::common::ids::ManagerId, NodeHandle> = handles
                .iter()
                .map(|h| (nodes[h].manager.id, *h))
                .collect();
            while let Some(task) = pending.pop_front() {
                match config.scheduler.route(task.container, &views, &mut rng) {
                    Some(mid) => {
                        let h = by_id[&mid];
                        // Update the local view optimistically so one
                        // routing pass spreads a burst across managers.
                        if let Some(v) = views.iter_mut().find(|v| v.id == mid) {
                            v.queued += 1;
                            // Deployed counts only shrink on eviction,
                            // which the manager reports via its next view.
                        }
                        nodes[&h].manager.enqueue(vec![task]);
                        stats.tasks_dispatched.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        pending.push_front(task);
                        break; // no capacity anywhere; try next tick
                    }
                }
            }
        }

        // 4. Return results upstream in batches.
        let mut results = Vec::new();
        while let Ok(r) = result_rx.try_recv() {
            results.push(r);
            if results.len() >= 256 {
                break;
            }
        }
        if !results.is_empty() {
            stats.results_returned.fetch_add(results.len() as u64, Ordering::Relaxed);
            if !link.send(Upstream::Results(results)) {
                break; // forwarder gone
            }
        }

        // 5. Strategy tick (§6.3) + container reaping (§6.1).
        if now - last_strategy_tick >= config.cfg.strategy_period_s {
            last_strategy_tick = now;
            let mut idle_workers = 0;
            let mut idle_nodes = Vec::new();
            for (h, slot) in nodes.iter_mut() {
                let v = slot.manager.view();
                idle_workers += v.available_slots.saturating_sub(v.queued);
                slot.manager.reap_idle(now);
                if slot.manager.is_idle() {
                    let since = *slot.idle_since.get_or_insert(now);
                    idle_nodes.push((*h, since));
                } else {
                    slot.idle_since = None;
                }
            }
            let inputs = StrategyInputs {
                now,
                pending_tasks: pending.len(),
                idle_workers,
                active_nodes: nodes.len(),
                pending_nodes: config.provider.pending_count(),
                idle_nodes,
            };
            let ScaleDecision { request_nodes, release } = strategy.decide(&inputs);
            if request_nodes > 0 {
                config.provider.request_nodes(request_nodes, now);
                stats.nodes_provisioned.fetch_add(request_nodes as u64, Ordering::Relaxed);
            }
            for h in release {
                if let Some(slot) = nodes.remove(&h) {
                    stats
                        .cold_starts
                        .fetch_add(slot.manager.cold_starts(), Ordering::Relaxed);
                    stats.warm_hits.fetch_add(slot.manager.warm_hits(), Ordering::Relaxed);
                    slot.manager.shutdown();
                    config.provider.release_node(h, now);
                    stats.nodes_released.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        // 6. Heartbeat (§4.1).
        if now - last_heartbeat >= config.heartbeat_period_s {
            last_heartbeat = now;
            let active: usize =
                nodes.values().map(|s| s.manager.view().total_slots).sum();
            stats.heartbeats_sent.fetch_add(1, Ordering::Relaxed);
            if !link.send(Upstream::Heartbeat {
                active_workers: active,
                pending_tasks: pending.len(),
            }) {
                break;
            }
        }
    }

    // Drain managers on exit, folding their pool stats into ours.
    for (_, slot) in nodes.drain() {
        stats.cold_starts.fetch_add(slot.manager.cold_starts(), Ordering::Relaxed);
        stats.warm_hits.fetch_add(slot.manager.warm_hits(), Ordering::Relaxed);
        slot.manager.shutdown();
    }
}
