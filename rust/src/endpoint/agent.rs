//! §4.3 — the funcX agent: the persistent per-endpoint process that
//! queues tasks, provisions nodes through the provider, routes tasks to
//! managers (§6.2), drives the elastic strategy (§6.3), and heartbeats
//! to its forwarder (§4.1).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::common::config::EndpointConfig;
use crate::common::ids::{ContainerId, ManagerId};
use crate::common::rng::Rng;
use crate::common::task::{Task, TaskResult};
use crate::common::time::{Clock, Time};
use crate::containers::StartCostModel;
use crate::datastore::DataFabric;
use crate::endpoint::link::{AgentSide, Downstream, Upstream};
use crate::endpoint::manager::{Manager, ManagerCtx};
use crate::metrics::{FlightRecorder, LatencyBreakdown, SnapshotBuilder, TraceKind};
use crate::provider::{NodeHandle, Provider, ScaleDecision, Strategy, StrategyInputs};
use crate::routing::{RouteHints, RoutingTable, Scheduler};
use crate::runtime::WorkerExecutor;

/// Shared, externally-readable agent statistics.
#[derive(Default)]
pub struct AgentStats {
    pub tasks_received: AtomicU64,
    pub tasks_dispatched: AtomicU64,
    pub results_returned: AtomicU64,
    pub cold_starts: AtomicU64,
    pub warm_hits: AtomicU64,
    pub nodes_provisioned: AtomicU64,
    pub nodes_released: AtomicU64,
    pub heartbeats_sent: AtomicU64,
    /// Slots warmed ahead of demand by predictive pool sizing.
    pub prewarmed: AtomicU64,
    /// Warm slots reaped below the predicted floor (scale-in half of
    /// predictive sizing; the idle-timeout reaper counts separately).
    pub predictive_reaps: AtomicU64,
}

impl AgentStats {
    /// Export every counter into a metrics snapshot under the given
    /// dimensions (typically `[("endpoint", <id>)]`).
    pub fn fill(&self, b: &mut SnapshotBuilder, dims: &[(&str, &str)]) {
        let o = Ordering::Relaxed;
        b.counter("funcx_agent_tasks_received_total", dims, self.tasks_received.load(o));
        b.counter("funcx_agent_tasks_dispatched_total", dims, self.tasks_dispatched.load(o));
        b.counter("funcx_agent_results_returned_total", dims, self.results_returned.load(o));
        b.counter("funcx_agent_cold_starts_total", dims, self.cold_starts.load(o));
        b.counter("funcx_agent_warm_hits_total", dims, self.warm_hits.load(o));
        b.counter("funcx_agent_nodes_provisioned_total", dims, self.nodes_provisioned.load(o));
        b.counter("funcx_agent_nodes_released_total", dims, self.nodes_released.load(o));
        b.counter("funcx_agent_heartbeats_sent_total", dims, self.heartbeats_sent.load(o));
        b.counter("funcx_agent_prewarmed_total", dims, self.prewarmed.load(o));
        b.counter("funcx_agent_predictive_reaps_total", dims, self.predictive_reaps.load(o));
    }
}

/// Per-container-type arrival-rate EWMA (tasks/second) — the demand
/// signal behind predictive warm-pool sizing (see `docs/containers.md`).
/// Arrivals are counted on intake; each strategy tick folds the window's
/// instantaneous rate into the EWMA, with silent types folding zero so
/// stale demand decays and its floors release their slots.
struct ArrivalPredictor {
    alpha: f64,
    counts: HashMap<ContainerId, u64>,
    rates: HashMap<ContainerId, f64>,
    last_tick: Option<Time>,
}

impl ArrivalPredictor {
    fn new(alpha: f64) -> Self {
        ArrivalPredictor {
            alpha: alpha.clamp(0.0, 1.0),
            counts: HashMap::new(),
            rates: HashMap::new(),
            last_tick: None,
        }
    }

    /// Count a task arrival for `ctype` (the nil id stands for bare
    /// tasks sharing the worker's own environment).
    fn observe(&mut self, ctype: ContainerId) {
        *self.counts.entry(ctype).or_insert(0) += 1;
    }

    /// Fold the window since the last tick into the per-type EWMAs.
    fn tick(&mut self, now: Time) {
        let dt = match self.last_tick {
            Some(t) if now > t => now - t,
            Some(_) => return,
            None => {
                self.last_tick = Some(now);
                self.counts.clear();
                return;
            }
        };
        self.last_tick = Some(now);
        for &c in self.counts.keys() {
            self.rates.entry(c).or_insert(0.0);
        }
        for (c, r) in self.rates.iter_mut() {
            let inst = self.counts.get(c).copied().unwrap_or(0) as f64 / dt;
            *r += self.alpha * (inst - *r);
        }
        self.rates.retain(|_, r| *r > 1e-6);
        self.counts.clear();
    }

    /// Predicted per-manager warm floors: `ceil(rate × cold_start ×
    /// safety)` slots endpoint-wide per type — enough warm capacity to
    /// absorb the arrivals that land during one cold start — split
    /// evenly across `managers`.
    fn floors(
        &self,
        cold_start_est_s: f64,
        safety: f64,
        managers: usize,
    ) -> HashMap<ContainerId, usize> {
        let mut floors = HashMap::new();
        if managers == 0 {
            return floors;
        }
        for (&c, &r) in &self.rates {
            let want = (r * cold_start_est_s.max(0.0) * safety).ceil() as usize;
            let per = want.div_ceil(managers);
            if per > 0 {
                floors.insert(c, per);
            }
        }
        floors
    }
}

/// Everything the agent needs at spawn time.
pub struct AgentConfig {
    pub cfg: EndpointConfig,
    pub provider: Box<dyn Provider>,
    pub scheduler: Box<dyn Scheduler>,
    /// Worker backend threaded into every manager: in-process (modeled
    /// start costs) or forked worker children (measured start costs).
    pub executor: Arc<dyn WorkerExecutor>,
    /// Data-fabric handle for resolving by-ref task inputs (§5);
    /// threaded into every manager's worker context.
    pub fabric: Option<Arc<DataFabric>>,
    pub clock: Arc<dyn Clock>,
    pub latency: Arc<LatencyBreakdown>,
    /// Flight recorder for agent/worker-side trace events; a disabled
    /// recorder (the builder default) makes every record a no-op.
    pub recorder: Arc<FlightRecorder>,
    pub start_model: StartCostModel,
    pub cold_start_scale: f64,
    pub heartbeat_period_s: f64,
    pub seed: u64,
}

/// Handle to a running agent thread.
pub struct AgentHandle {
    pub stats: Arc<AgentStats>,
    thread: Option<JoinHandle<()>>,
}

impl AgentHandle {
    /// Spawn the agent loop servicing `link`.
    pub fn spawn(link: AgentSide, config: AgentConfig) -> Self {
        let stats = Arc::new(AgentStats::default());
        let st = stats.clone();
        let thread = std::thread::Builder::new()
            .name("funcx-agent".into())
            .spawn(move || agent_loop(link, config, st))
            .expect("spawn agent");
        AgentHandle { stats, thread: Some(thread) }
    }

    /// Wait for the agent to exit (after a Shutdown message or severed
    /// link).
    pub fn join(mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

struct NodeSlot {
    manager: Manager,
    idle_since: Option<Time>,
}

fn agent_loop(link: AgentSide, mut config: AgentConfig, stats: Arc<AgentStats>) {
    // Shared task handles end to end: intake, routing, and manager
    // enqueue move the same Arc the forwarder dispatched.
    let mut pending: VecDeque<Arc<Task>> = VecDeque::new();
    let mut nodes: HashMap<NodeHandle, NodeSlot> = HashMap::new();
    // ManagerId → node handle, maintained alongside `nodes`.
    let mut by_id: HashMap<ManagerId, NodeHandle> = HashMap::new();
    // Managers send result *batches* (size/idle-flushed ResultBuffer).
    let (result_tx, result_rx): (Sender<Vec<TaskResult>>, Receiver<Vec<TaskResult>>) =
        channel();
    // One latch, three wake sources: downstream link traffic (wired in
    // by `link()`), worker results (via ManagerCtx), and link death.
    let wake = link.wake_handle();
    // Incrementally-maintained routing indexes: views are refreshed once
    // per dispatch pass (skipping unchanged managers), then a whole
    // burst routes at O(log M) per task.
    let mut table = RoutingTable::new(config.scheduler.prefetch());
    let strategy = Strategy::new(config.cfg.clone());
    let mut rng = Rng::new(config.seed);
    let mut predictor = ArrivalPredictor::new(config.cfg.arrival_ewma_alpha);
    let nil_container = ContainerId(crate::Uuid::NIL);
    let endpoint_id = config.fabric.as_ref().map(|f| f.local().owner());
    let mut last_strategy_tick: Time = f64::NEG_INFINITY;
    let mut last_heartbeat: Time = f64::NEG_INFINITY;

    // Advertise this endpoint's store before anything else crosses the
    // link (§5 peer auto-discovery): the forwarder peers the service
    // fabric with it, so `rref` results resolve without manual wiring.
    // FIFO ordering guarantees the advertisement lands before any
    // result that might carry a ref into that store.
    if let Some(fabric) = &config.fabric {
        if !link.send(Upstream::Advertise(fabric.local().clone())) {
            return;
        }
    }

    // Pre-provision the configured minimum.
    if config.cfg.min_nodes > 0 {
        let now = config.clock.now();
        config.provider.request_nodes(config.cfg.min_nodes, now);
        stats.nodes_provisioned.fetch_add(config.cfg.min_nodes as u64, Ordering::Relaxed);
    }

    'outer: loop {
        let now = config.clock.now();
        // Epoch snapshot before the work checks: traffic or results
        // arriving during the pass void the idle wait at the bottom.
        let seen = wake.epoch();
        let mut progressed = false;

        // 1. Intake from the forwarder (drain everything available).
        while let Some(msg) = link.try_recv() {
            progressed = true;
            match msg {
                Downstream::Tasks(ts) => {
                    stats.tasks_received.fetch_add(ts.len() as u64, Ordering::Relaxed);
                    for t in &ts {
                        predictor.observe(t.container.unwrap_or(nil_container));
                    }
                    pending.extend(ts);
                }
                Downstream::Advertise(store) => {
                    // The service's payload store: peer our fabric with
                    // it so workers resolve `iref` inputs without manual
                    // wiring.
                    if let Some(fabric) = &config.fabric {
                        fabric.connect_peer(store.owner(), store);
                    }
                }
                Downstream::Ping => {}
                Downstream::Shutdown => break 'outer,
                Downstream::Decommission => {
                    // Orderly retirement (§4.1 churn): stop taking work,
                    // drain the managers (flushing their buffered
                    // results first), return everything that finished,
                    // and sign off with Deregister — the forwarder then
                    // requeues what we never ran and retires the
                    // endpoint service-side (frame drain, store
                    // withdrawal, spool GC).
                    for (_, slot) in nodes.drain() {
                        slot.manager.flush_results();
                        stats
                            .cold_starts
                            .fetch_add(slot.manager.cold_starts(), Ordering::Relaxed);
                        stats.warm_hits.fetch_add(slot.manager.warm_hits(), Ordering::Relaxed);
                        by_id.remove(&slot.manager.id);
                        slot.manager.shutdown();
                    }
                    let mut results = Vec::new();
                    while let Ok(mut batch) = result_rx.try_recv() {
                        results.append(&mut batch);
                    }
                    if !results.is_empty() {
                        stats
                            .results_returned
                            .fetch_add(results.len() as u64, Ordering::Relaxed);
                        link.send(Upstream::Results(results));
                    }
                    link.send(Upstream::Deregister);
                    // Hold our side of the link open until the
                    // forwarder consumes the sign-off: returning now
                    // would sever the link and discard the queued
                    // Results/Deregister before the peer drains them.
                    // The forwarder drops its side once it has
                    // processed Deregister, which ends this wait.
                    while link.is_alive() {
                        let _ = link.recv_timeout(Duration::from_millis(20));
                    }
                    return;
                }
            }
        }
        if !link.is_alive() {
            break;
        }

        // 2. Activate nodes that cleared the provider queue.
        for h in config.provider.poll(now) {
            progressed = true;
            let ctx = ManagerCtx {
                executor: config.executor.clone(),
                results: result_tx.clone(),
                wake: wake.clone(),
                result_batch: config.cfg.result_batch,
                fabric: config.fabric.clone(),
                endpoint: config.fabric.as_ref().map(|f| f.local().owner()),
                max_result_bytes: config.cfg.max_result_bytes,
                clock: config.clock.clone(),
                latency: config.latency.clone(),
                recorder: config.recorder.clone(),
                start_model: config.start_model,
                cold_start_scale: config.cold_start_scale,
                pipeline_depth: config.cfg.worker_pipeline_depth,
            };
            let m = Manager::spawn(
                config.cfg.workers_per_node,
                config.cfg.container_idle_timeout_s,
                ctx,
                rng.next_u64(),
            );
            by_id.insert(m.id, h);
            table.upsert(m.view());
            nodes.insert(h, NodeSlot { manager: m, idle_since: None });
        }

        // 3. Route pending tasks to managers (§6.2).
        if !pending.is_empty() && !nodes.is_empty() {
            // Refresh the table from live manager state — one O(M) pass
            // amortized over the whole burst, no-op for unchanged views.
            for slot in nodes.values() {
                table.sync(slot.manager.view());
            }
            while let Some(task) = pending.pop_front() {
                // Hinted routing: a by-ref task names its data's owner
                // so LocalityAware can route it to the store; every
                // other policy ignores the hints (trait default).
                let hints = RouteHints::for_task(task.as_ref());
                match config.scheduler.route_hinted_indexed(task.container, hints, &table, &mut rng)
                {
                    Some(mid) => {
                        progressed = true;
                        let h = by_id[&mid];
                        // Update the table optimistically so one routing
                        // pass spreads a burst across managers. (Deployed
                        // counts only shrink on eviction, which the
                        // manager reports via its next view.)
                        table.update(mid, |v| v.queued += 1);
                        if config.recorder.enabled() {
                            config.recorder.record(
                                &format!("endpoint-{}", task.endpoint),
                                task.trace,
                                Some(task.id),
                                now,
                                TraceKind::AgentDispatched { endpoint: task.endpoint },
                            );
                        }
                        nodes[&h].manager.enqueue(vec![task]);
                        stats.tasks_dispatched.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        pending.push_front(task);
                        break; // no capacity anywhere; results re-wake us
                    }
                }
            }
        }

        // 4. Return results upstream in batches.
        let mut results = Vec::new();
        while let Ok(mut batch) = result_rx.try_recv() {
            results.append(&mut batch);
            if results.len() >= 1024 {
                break;
            }
        }
        if !results.is_empty() {
            progressed = true;
            stats.results_returned.fetch_add(results.len() as u64, Ordering::Relaxed);
            if !link.send(Upstream::Results(results)) {
                break; // forwarder gone
            }
        }

        // 5. Strategy tick (§6.3) + container reaping (§6.1).
        if now - last_strategy_tick >= config.cfg.strategy_period_s {
            last_strategy_tick = now;
            let mut idle_workers = 0;
            let mut idle_nodes = Vec::new();
            for (h, slot) in nodes.iter_mut() {
                let v = slot.manager.view();
                idle_workers += v.available_slots.saturating_sub(v.queued);
                slot.manager.reap_idle(now);
                if slot.manager.is_idle() {
                    let since = *slot.idle_since.get_or_insert(now);
                    idle_nodes.push((*h, since));
                } else {
                    slot.idle_since = None;
                }
            }
            let inputs = StrategyInputs {
                now,
                pending_tasks: pending.len(),
                idle_workers,
                active_nodes: nodes.len(),
                pending_nodes: config.provider.pending_count(),
                idle_nodes,
            };
            let ScaleDecision { request_nodes, release } = strategy.decide(&inputs);
            if request_nodes > 0 {
                config.provider.request_nodes(request_nodes, now);
                stats.nodes_provisioned.fetch_add(request_nodes as u64, Ordering::Relaxed);
            }
            for h in release {
                if let Some(slot) = nodes.remove(&h) {
                    stats
                        .cold_starts
                        .fetch_add(slot.manager.cold_starts(), Ordering::Relaxed);
                    stats.warm_hits.fetch_add(slot.manager.warm_hits(), Ordering::Relaxed);
                    by_id.remove(&slot.manager.id);
                    table.remove(slot.manager.id);
                    slot.manager.shutdown();
                    config.provider.release_node(h, now);
                    stats.nodes_released.fetch_add(1, Ordering::Relaxed);
                }
            }

            // Predictive warm-pool sizing (§6.1 economics, see
            // docs/containers.md): fold this tick's arrivals into the
            // per-type rate EWMAs, then size every surviving manager's
            // warm floor off its *own* cold-start estimate — measured
            // starts where the backend reports them, the Table-3 prior
            // otherwise — prewarming ahead of routed load and reaping
            // idle slots the prediction no longer justifies.
            if config.cfg.predictive_sizing && !nodes.is_empty() {
                predictor.tick(now);
                let n_managers = nodes.len();
                for slot in nodes.values() {
                    let v = slot.manager.view();
                    let floors = predictor.floors(
                        v.cold_start_est_s,
                        config.cfg.warm_floor_safety,
                        n_managers,
                    );
                    let (warmed, reaped) = slot.manager.apply_warm_plan(
                        &floors,
                        config.cfg.predictive_reap_grace_s,
                        now,
                    );
                    if warmed > 0 {
                        stats.prewarmed.fetch_add(warmed as u64, Ordering::Relaxed);
                        if config.recorder.enabled() {
                            if let Some(ep) = endpoint_id {
                                config.recorder.record(
                                    &format!("endpoint-{ep}"),
                                    None,
                                    None,
                                    now,
                                    TraceKind::Prewarmed { endpoint: ep, count: warmed as u32 },
                                );
                            }
                        }
                    }
                    if reaped > 0 {
                        let n = reaped as u64;
                        stats.predictive_reaps.fetch_add(n, Ordering::Relaxed);
                    }
                }
            }
        }

        // 6. Heartbeat (§4.1).
        if now - last_heartbeat >= config.heartbeat_period_s {
            last_heartbeat = now;
            let active: usize =
                nodes.values().map(|s| s.manager.view().total_slots).sum();
            stats.heartbeats_sent.fetch_add(1, Ordering::Relaxed);
            if !link.send(Upstream::Heartbeat {
                active_workers: active,
                pending_tasks: pending.len(),
            }) {
                break;
            }
        }

        // 7. Idle wait: block until link traffic or a worker result,
        // bounded by the next timer deadline (strategy tick, heartbeat,
        // or a short provider re-poll while nodes are provisioning).
        // First flush straggler results still sitting in manager buffers
        // (buffered because the manager queue wasn't idle at push time);
        // anything flushed re-arms the loop via the shared wake latch.
        if !progressed {
            for slot in nodes.values() {
                slot.manager.flush_results();
            }
        }
        if !progressed {
            let mut next = (last_strategy_tick + config.cfg.strategy_period_s)
                .min(last_heartbeat + config.heartbeat_period_s);
            if config.provider.pending_count() > 0 {
                // The provider is pull-only; re-poll soon while nodes
                // are in its queue.
                next = next.min(now + 1e-3);
            }
            let dur = (next - config.clock.now()).clamp(1e-4, 0.5);
            wake.wait_newer(seen, Duration::from_secs_f64(dur));
        }
    }

    // Drain managers on exit, folding their pool stats into ours.
    for (_, slot) in nodes.drain() {
        stats.cold_starts.fetch_add(slot.manager.cold_starts(), Ordering::Relaxed);
        stats.warm_hits.fetch_add(slot.manager.warm_hits(), Ordering::Relaxed);
        slot.manager.shutdown();
    }
}
