//! Experiment harnesses — one function per table/figure in §7.
//!
//! Each harness regenerates the corresponding evaluation artifact
//! (workload, sweep, baseline, and the same rows/series the paper
//! reports) and returns structured rows so the benches, the CLI
//! (`funcx bench-*`), and the integration tests share one code path.
//! See DESIGN.md §4 for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured.

use crate::common::ids::ContainerId;
use crate::common::rng::Rng;
use crate::containers::TABLE3_MODELS;
use crate::data::{CommPattern, Transport, TransportModel};
use crate::routing::{Randomized, Scheduler, WarmingAware};
use crate::sim::{SimEndpoint, SimProfile, SimTask};
use crate::workloads;

// ---------------------------------------------------------------------------
// E2/E3/E4 — Fig. 4 scaling + §7.2.3 throughput
// ---------------------------------------------------------------------------

/// One scaling datapoint.
#[derive(Clone, Copy, Debug)]
pub struct ScalingPoint {
    pub containers: usize,
    pub completion_s: f64,
    pub throughput: f64,
}

fn scaled_endpoint(profile: SimProfile, containers: usize) -> SimEndpoint {
    let nodes = containers.div_ceil(profile.workers_per_node).max(1);
    let mut p = profile;
    // Allow partial nodes so small container counts are exact.
    if containers < profile.workers_per_node {
        p.workers_per_node = containers;
    }
    let mut ep = SimEndpoint::new(p, nodes, Box::new(WarmingAware::default()), true, 42)
        .deterministic_cold(true);
    ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
    ep
}

/// Fig. 4(a) strong scaling: fixed task count, growing container counts.
pub fn fig4_strong(
    profile: SimProfile,
    total_tasks: usize,
    duration_s: f64,
    container_counts: &[usize],
) -> Vec<ScalingPoint> {
    let tasks = workloads::sleeps(total_tasks, duration_s);
    container_counts
        .iter()
        .map(|&c| {
            let r = scaled_endpoint(profile, c).run(&tasks);
            ScalingPoint { containers: c, completion_s: r.completion_s, throughput: r.throughput }
        })
        .collect()
}

/// Fig. 4(b) weak scaling: fixed tasks *per container*.
pub fn fig4_weak(
    profile: SimProfile,
    tasks_per_container: usize,
    duration_s: f64,
    container_counts: &[usize],
) -> Vec<ScalingPoint> {
    container_counts
        .iter()
        .map(|&c| {
            let tasks = workloads::sleeps(tasks_per_container * c, duration_s);
            let r = scaled_endpoint(profile, c).run(&tasks);
            ScalingPoint { containers: c, completion_s: r.completion_s, throughput: r.throughput }
        })
        .collect()
}

/// §7.2.3 peak agent throughput.
pub fn peak_throughput(profile: SimProfile) -> f64 {
    let tasks = workloads::noops(50_000);
    scaled_endpoint(profile, 8 * profile.workers_per_node).run(&tasks).throughput
}

// ---------------------------------------------------------------------------
// E5 — Fig. 5 intra-endpoint transfer approaches
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct TransferPoint {
    pub transport: Transport,
    pub pattern: CommPattern,
    pub size_bytes: usize,
    pub time_s: f64,
}

/// Fig. 5: 4 transports x 3 patterns x size sweep.
pub fn fig5_transfer(sizes: &[usize]) -> Vec<TransferPoint> {
    let patterns = [
        CommPattern::PointToPoint,
        CommPattern::Broadcast { nodes: 20 },
        CommPattern::AllToAll { nodes: 20 },
    ];
    let mut out = Vec::new();
    for pattern in patterns {
        for transport in Transport::ALL {
            let model = TransportModel::theta(transport);
            for &size in sizes {
                out.push(TransferPoint {
                    transport,
                    pattern,
                    size_bytes: size,
                    time_s: model.pattern_time(pattern, size),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// E6 — Table 1 MapReduce
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct MapReduceRow {
    pub app: &'static str,
    pub transport: Transport,
    pub phases: workloads::MapReducePhases,
}

/// Table 1: WordCount & Sort phase times under Redis vs sharedFS.
pub fn table1_mapreduce() -> Vec<MapReduceRow> {
    let mut out = Vec::new();
    for (app, spec) in [
        ("WordCount", workloads::MapReduceSpec::wordcount_paper()),
        ("Sort", workloads::MapReduceSpec::sort_paper()),
    ] {
        for transport in [Transport::InMemoryStore, Transport::SharedFs] {
            out.push(MapReduceRow {
                app,
                transport,
                phases: workloads::mapreduce_phases(&spec, transport, 300),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// E7 — Table 2 Colmena
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct ColmenaRow {
    pub transport: Transport,
    pub stages: workloads::ColmenaStages,
}

/// Table 2: Colmena's four communication stages (1000 tasks, 1 MB each).
pub fn table2_colmena() -> Vec<ColmenaRow> {
    [Transport::InMemoryStore, Transport::SharedFs]
        .into_iter()
        .map(|transport| ColmenaRow {
            transport,
            stages: workloads::colmena_stages(transport, 1 << 20, 100),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// E8 — Table 3 container instantiation
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct ContainerRow {
    pub system: &'static str,
    pub container: &'static str,
    pub min_s: f64,
    pub max_s: f64,
    pub mean_s: f64,
}

/// Table 3: sampled cold-start statistics per (system, tech).
pub fn table3_containers(samples: usize, seed: u64) -> Vec<ContainerRow> {
    let mut rng = Rng::new(seed);
    TABLE3_MODELS
        .all()
        .into_iter()
        .map(|m| {
            let xs: Vec<f64> = (0..samples).map(|_| m.sample(&mut rng)).collect();
            let sum: f64 = xs.iter().sum();
            ContainerRow {
                system: m.system.name(),
                container: m.tech.name(),
                min_s: xs.iter().cloned().fold(f64::INFINITY, f64::min),
                max_s: xs.iter().cloned().fold(0.0, f64::max),
                mean_s: sum / samples as f64,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// E9/E10 — Figs. 6–7 warming-aware vs random routing
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct RoutingPoint {
    pub batch: usize,
    pub duration_s: f64,
    pub warming_completion_s: f64,
    pub random_completion_s: f64,
    pub warming_cold_starts: u64,
    pub random_cold_starts: u64,
}

/// Figs. 6–7 setup: 10 nodes x 10 workers, 10 function/container types,
/// uniform-random batches, four function durations.
pub fn fig6_fig7_routing(batches: &[usize], durations: &[f64], seed: u64) -> Vec<RoutingPoint> {
    let types = workloads::ten_container_types();
    let mut profile = SimProfile::theta();
    profile.workers_per_node = 10;
    let mut out = Vec::new();
    for &duration in durations {
        for &batch in batches {
            let mut rng = Rng::new(seed ^ batch as u64);
            let tasks = workloads::uniform_container_mix(batch, &types, duration, &mut rng);
            let run = |sched: Box<dyn Scheduler>, s2: u64| {
                SimEndpoint::new(profile, 10, sched, true, s2)
                    .deterministic_cold(true)
                    .run(&tasks)
            };
            let wa = run(Box::new(WarmingAware { prefetch: 10 }), seed);
            let rnd = run(Box::new(Randomized { prefetch: 10 }), seed);
            out.push(RoutingPoint {
                batch,
                duration_s: duration,
                warming_completion_s: wa.completion_s,
                random_completion_s: rnd.completion_s,
                warming_cold_starts: wa.cold_starts,
                random_cold_starts: rnd.cold_starts,
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// E11 — §7.5 batching ablation
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct BatchingResult {
    pub batched_s: f64,
    pub unbatched_s: f64,
}

/// §7.5: 10 000 no-ops on 4 Theta nodes (256 containers), internal
/// batching on vs off.
pub fn batching_ablation() -> BatchingResult {
    let tasks = workloads::noops(10_000);
    let run = |batching| {
        let mut ep = SimEndpoint::new(
            SimProfile::theta(),
            4,
            Box::new(WarmingAware::default()),
            batching,
            1,
        )
        .deterministic_cold(true);
        ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
        ep.run(&tasks).completion_s
    };
    BatchingResult { batched_s: run(true), unbatched_s: run(false) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_strong_decreases_then_flattens() {
        let pts = fig4_strong(SimProfile::theta(), 10_000, 0.0, &[64, 256, 1024]);
        assert!(pts[0].completion_s > pts[1].completion_s);
        let flat = pts[1].completion_s / pts[2].completion_s;
        assert!(flat < 1.4, "no-op flattens past 256: {flat}");
    }

    #[test]
    fn fig4_weak_noop_grows() {
        let pts = fig4_weak(SimProfile::theta(), 10, 0.0, &[64, 1024]);
        assert!(pts[1].completion_s > pts[0].completion_s);
    }

    #[test]
    fn fig5_has_all_cells() {
        let pts = fig5_transfer(&[1024, 1 << 20]);
        assert_eq!(pts.len(), 3 * 4 * 2);
    }

    #[test]
    fn table1_shuffle_speedup_and_ordering() {
        // Table 1's claims: Redis speeds the shuffle (intermediate
        // write/read) by up to ~3x; Sort gains proportionally more than
        // WordCount overall (55.7% vs 18.2% in the paper).
        let rows = table1_mapreduce();
        let row = |app: &str, t: Transport| {
            rows.iter().find(|r| r.app == app && r.transport == t).unwrap().phases
        };
        for app in ["Sort", "WordCount"] {
            let redis = row(app, Transport::InMemoryStore);
            let fs = row(app, Transport::SharedFs);
            let read_speedup = fs.intermediate_read_s / redis.intermediate_read_s;
            assert!(
                (1.5..6.0).contains(&read_speedup),
                "{app}: shuffle-read speedup {read_speedup}"
            );
            assert!(fs.intermediate_write_s > redis.intermediate_write_s);
        }
        let total = |app: &str, t: Transport| row(app, t).total();
        let sort_gain = 1.0
            - total("Sort", Transport::InMemoryStore) / total("Sort", Transport::SharedFs);
        let wc_gain = 1.0
            - total("WordCount", Transport::InMemoryStore)
                / total("WordCount", Transport::SharedFs);
        assert!(sort_gain > wc_gain, "sort {sort_gain} vs wordcount {wc_gain}");
    }

    #[test]
    fn table3_matches_paper_rows() {
        let rows = table3_containers(5000, 7);
        let theta = rows.iter().find(|r| r.system == "theta").unwrap();
        assert!((theta.mean_s - 10.40).abs() < 1.0);
        let ec2: Vec<_> = rows.iter().filter(|r| r.system == "ec2").collect();
        assert_eq!(ec2.len(), 2);
        for r in ec2 {
            assert!(r.mean_s < 2.0);
        }
    }

    #[test]
    fn routing_gap_shrinks_with_duration() {
        let pts = fig6_fig7_routing(&[1000], &[0.0, 20.0], 3);
        let gain = |p: &RoutingPoint| {
            (p.random_completion_s - p.warming_completion_s) / p.random_completion_s
        };
        assert!(gain(&pts[0]) > gain(&pts[1]), "benefit must shrink with duration");
        assert!(pts[0].warming_cold_starts < pts[0].random_cold_starts);
    }
}
