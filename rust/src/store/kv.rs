//! The key-value core: strings + TTL, hashes, lists, counters.
//!
//! # Lock striping
//!
//! The store is split into [`N_SHARDS`] shards, each guarded by its own
//! `Mutex + Condvar`; a key's shard is picked by an FNV-1a hash of the
//! key bytes. Every key lives entirely inside one shard, so single-key
//! operations stay linearizable (per-key FIFO for the queues) while
//! operations on *different* keys proceed in parallel — the property the
//! forwarder fleet needs, since each endpoint has its own task/result
//! queue keys. This mirrors a clustered Redis: single-threaded per
//! shard, sharded by key hash.
//!
//! # Wakeups
//!
//! Blocking pops ([`KvStore::blpop`], [`KvStore::blpop_n`]) wait on the
//! owning shard's condvar and are woken by pushes to that shard. In
//! addition, a consumer can register a [`Notify`] watch on a key
//! ([`KvStore::add_watch`]); pushes to that key signal the watch, which
//! lets a control loop block on *several* wake sources (its link and its
//! queue) through one handle instead of polling. Watches are held weakly
//! and pruned once the watcher drops its handle.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::common::sync::Notify;
use crate::common::time::Time;
use crate::serialize::Buffer;

/// Number of lock stripes. A small power of two: enough to keep a
/// forwarder fleet's queue keys from contending, cheap to scan for
/// store-wide ops (purge).
const N_SHARDS: usize = 16;

#[derive(Default)]
struct Shard {
    strings: HashMap<String, (Buffer, Option<Time>)>,
    hashes: HashMap<String, HashMap<String, Buffer>>,
    lists: HashMap<String, VecDeque<Buffer>>,
    counters: HashMap<String, i64>,
    /// Key → weakly-held wakeup latches signalled on pushes to the key.
    watchers: HashMap<String, Vec<Weak<Notify>>>,
}

impl Shard {
    /// Upgrade (and prune) the watchers registered for `key`.
    fn live_watchers(&mut self, key: &str) -> Vec<Arc<Notify>> {
        let live: Vec<Arc<Notify>> = match self.watchers.get_mut(key) {
            Some(ws) => {
                ws.retain(|w| w.strong_count() > 0);
                ws.iter().filter_map(Weak::upgrade).collect()
            }
            None => Vec::new(),
        };
        if live.is_empty() {
            // No live watchers left (or none registered): drop the slot.
            self.watchers.remove(key);
        }
        live
    }
}

struct ShardCell {
    data: Mutex<Shard>,
    cv: Condvar,
}

impl Default for ShardCell {
    fn default() -> Self {
        ShardCell { data: Mutex::new(Shard::default()), cv: Condvar::new() }
    }
}

/// An in-process Redis-subset store. Cheap to clone (Arc inside); all
/// operations on one key are linearizable under that key's shard mutex —
/// funcX's Redis is single-threaded per shard too, so this matches the
/// consistency model the paper's queues rely on, while distinct keys
/// (distinct endpoints' queues) no longer serialize behind one lock.
#[derive(Clone)]
pub struct KvStore {
    shards: Arc<Vec<ShardCell>>,
}

impl Default for KvStore {
    fn default() -> Self {
        Self::new()
    }
}

impl KvStore {
    pub fn new() -> Self {
        KvStore {
            shards: Arc::new((0..N_SHARDS).map(|_| ShardCell::default()).collect()),
        }
    }

    fn cell(&self, key: &str) -> &ShardCell {
        // FNV-1a over the key bytes.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in key.as_bytes() {
            h = (h ^ u64::from(*b)).wrapping_mul(0x0000_0100_0000_01b3);
        }
        &self.shards[(h as usize) % self.shards.len()]
    }

    fn lock(&self, key: &str) -> std::sync::MutexGuard<'_, Shard> {
        self.cell(key).data.lock().expect("kv store poisoned")
    }

    /// Register a wakeup latch signalled whenever `key` receives a push.
    /// The store holds the latch weakly: drop your `Arc` and the watch
    /// disappears on the next push.
    pub fn add_watch(&self, key: &str, notify: Arc<Notify>) {
        self.lock(key)
            .watchers
            .entry(key.to_string())
            .or_default()
            .push(Arc::downgrade(&notify));
    }

    // ---- strings ---------------------------------------------------------

    /// SET key value (no expiry). Values are shared [`Buffer`]s: the
    /// store keeps a refcounted handle, never a copy.
    pub fn set(&self, key: &str, value: impl Into<Buffer>) {
        self.lock(key).strings.insert(key.to_string(), (value.into(), None));
    }

    /// SETEX: set with a TTL relative to `now` (caller supplies the clock
    /// reading so the simulator can drive expiry under virtual time).
    pub fn set_ex(&self, key: &str, value: impl Into<Buffer>, ttl_s: f64, now: Time) {
        self.lock(key).strings.insert(key.to_string(), (value.into(), Some(now + ttl_s)));
    }

    /// GET at an explicit time (TTL-aware). O(1): returns another handle
    /// on the stored allocation, not a copy of the bytes.
    pub fn get_at(&self, key: &str, now: Time) -> Option<Buffer> {
        let mut g = self.lock(key);
        match g.strings.get(key) {
            Some((_, Some(exp))) if now >= *exp => {
                g.strings.remove(key);
                None
            }
            Some((v, _)) => Some(v.clone()),
            None => None,
        }
    }

    /// GET ignoring TTL bookkeeping (keys set without expiry).
    pub fn get(&self, key: &str) -> Option<Buffer> {
        self.get_at(key, 0.0)
    }

    /// DEL; removes every type stored under the key (string, hash, list,
    /// counter). Returns whether the key existed in any of them.
    pub fn del(&self, key: &str) -> bool {
        let mut g = self.lock(key);
        g.strings.remove(key).is_some()
            | g.hashes.remove(key).is_some()
            | g.lists.remove(key).is_some()
            | g.counters.remove(key).is_some()
    }

    /// Purge every expired string key (the service's periodic result
    /// purge; §4.1). Returns the number purged.
    pub fn purge_expired(&self, now: Time) -> usize {
        let mut purged = 0;
        for cell in self.shards.iter() {
            let mut g = cell.data.lock().expect("kv store poisoned");
            let before = g.strings.len();
            g.strings.retain(|_, (_, exp)| exp.map_or(true, |e| now < e));
            purged += before - g.strings.len();
        }
        purged
    }

    // ---- hashes ----------------------------------------------------------

    pub fn hset(&self, key: &str, field: &str, value: impl Into<Buffer>) {
        self.lock(key)
            .hashes
            .entry(key.to_string())
            .or_default()
            .insert(field.to_string(), value.into());
    }

    pub fn hget(&self, key: &str, field: &str) -> Option<Buffer> {
        self.lock(key).hashes.get(key).and_then(|h| h.get(field).cloned())
    }

    pub fn hdel(&self, key: &str, field: &str) -> bool {
        self.lock(key)
            .hashes
            .get_mut(key)
            .map(|h| h.remove(field).is_some())
            .unwrap_or(false)
    }

    pub fn hlen(&self, key: &str) -> usize {
        self.lock(key).hashes.get(key).map(|h| h.len()).unwrap_or(0)
    }

    pub fn hkeys(&self, key: &str) -> Vec<String> {
        self.lock(key)
            .hashes
            .get(key)
            .map(|h| h.keys().cloned().collect())
            .unwrap_or_default()
    }

    // ---- lists (queues) ---------------------------------------------------

    /// RPUSH: append to the tail; wakes blocked poppers and watchers.
    /// O(1) in payload size — the queue holds a handle on the frame.
    pub fn rpush(&self, key: &str, value: impl Into<Buffer>) -> usize {
        let cell = self.cell(key);
        let mut g = cell.data.lock().expect("kv store poisoned");
        let l = g.lists.entry(key.to_string()).or_default();
        l.push_back(value.into());
        let n = l.len();
        let watchers = g.live_watchers(key);
        drop(g);
        cell.cv.notify_all();
        for w in watchers {
            w.notify();
        }
        n
    }

    /// Batched RPUSH: append several values under ONE lock acquisition
    /// and issue ONE wakeup set for the whole flush — producer-side
    /// watch coalescing. A burst of B frames costs each watcher one
    /// `Notify` instead of B (and blocked poppers one condvar broadcast),
    /// so a producer flushing batches cannot drown its consumers in
    /// redundant wakeups. Returns the list length after the append; a
    /// no-op (no lock, no wakeup) for an empty batch.
    pub fn rpush_many(&self, key: &str, values: Vec<Buffer>) -> usize {
        if values.is_empty() {
            return self.llen(key);
        }
        let cell = self.cell(key);
        let mut g = cell.data.lock().expect("kv store poisoned");
        let l = g.lists.entry(key.to_string()).or_default();
        for v in values {
            l.push_back(v);
        }
        let n = l.len();
        let watchers = g.live_watchers(key);
        drop(g);
        cell.cv.notify_all();
        for w in watchers {
            w.notify();
        }
        n
    }

    /// LPUSH: prepend to the head (used to *return* undelivered tasks to
    /// the front of the queue on agent loss; §4.1).
    pub fn lpush(&self, key: &str, value: impl Into<Buffer>) -> usize {
        let cell = self.cell(key);
        let mut g = cell.data.lock().expect("kv store poisoned");
        let l = g.lists.entry(key.to_string()).or_default();
        l.push_front(value.into());
        let n = l.len();
        let watchers = g.live_watchers(key);
        drop(g);
        cell.cv.notify_all();
        for w in watchers {
            w.notify();
        }
        n
    }

    /// LPOP: pop from the head.
    pub fn lpop(&self, key: &str) -> Option<Buffer> {
        self.lock(key).lists.get_mut(key).and_then(|l| l.pop_front())
    }

    /// Pop up to `n` items (pipelined LPOP — the batching fast path).
    pub fn lpop_n(&self, key: &str, n: usize) -> Vec<Buffer> {
        let mut g = self.lock(key);
        match g.lists.get_mut(key) {
            Some(l) => {
                let take = n.min(l.len());
                l.drain(..take).collect()
            }
            None => Vec::new(),
        }
    }

    /// BLPOP: block until an item arrives or `timeout` elapses.
    pub fn blpop(&self, key: &str, timeout: Duration) -> Option<Buffer> {
        self.blpop_n(key, 1, timeout).pop()
    }

    /// Batched BLPOP: block until the list is non-empty (or `timeout`
    /// elapses), then drain up to `max` items in one call. Consumers get
    /// push-driven wakeups *and* internal batching in a single op — for
    /// single-queue consumers. (The forwarder multiplexes several wake
    /// sources instead: it pairs non-blocking [`KvStore::lpop_n`] with an
    /// [`KvStore::add_watch`] latch shared with its agent link.)
    pub fn blpop_n(&self, key: &str, max: usize, timeout: Duration) -> Vec<Buffer> {
        if max == 0 {
            return Vec::new();
        }
        let cell = self.cell(key);
        let deadline = Instant::now() + timeout;
        let mut g = cell.data.lock().expect("kv store poisoned");
        loop {
            if let Some(l) = g.lists.get_mut(key) {
                if !l.is_empty() {
                    let take = max.min(l.len());
                    return l.drain(..take).collect();
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Vec::new();
            }
            let (guard, timed_out) =
                cell.cv.wait_timeout(g, remaining).expect("kv store poisoned");
            g = guard;
            if timed_out.timed_out() {
                // Re-check once after timeout to avoid a lost-wakeup race.
                return match g.lists.get_mut(key) {
                    Some(l) => {
                        let take = max.min(l.len());
                        l.drain(..take).collect()
                    }
                    None => Vec::new(),
                };
            }
        }
    }

    pub fn llen(&self, key: &str) -> usize {
        self.lock(key).lists.get(key).map(|l| l.len()).unwrap_or(0)
    }

    // ---- counters ----------------------------------------------------------

    pub fn incr(&self, key: &str) -> i64 {
        let mut g = self.lock(key);
        let c = g.counters.entry(key.to_string()).or_insert(0);
        *c += 1;
        *c
    }

    pub fn counter(&self, key: &str) -> i64 {
        *self.lock(key).counters.get(key).unwrap_or(&0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn string_set_get_del() {
        let kv = KvStore::new();
        kv.set("a", b"1".to_vec());
        assert_eq!(kv.get("a"), Some(b"1".into()));
        assert!(kv.del("a"));
        assert_eq!(kv.get("a"), None);
        assert!(!kv.del("a"));
    }

    #[test]
    fn del_clears_every_type() {
        let kv = KvStore::new();
        kv.set("k", b"s".to_vec());
        kv.hset("k", "f", b"h".to_vec());
        kv.rpush("k", b"l".to_vec());
        kv.incr("k");
        assert!(kv.del("k"));
        assert_eq!(kv.get("k"), None);
        assert_eq!(kv.hget("k", "f"), None);
        assert_eq!(kv.llen("k"), 0);
        assert_eq!(kv.counter("k"), 0, "del must clear counters too");
        assert!(!kv.del("k"));
        // A counter-only key is deletable as well.
        kv.incr("c");
        assert!(kv.del("c"));
        assert_eq!(kv.counter("c"), 0);
    }

    #[test]
    fn ttl_and_purge() {
        let kv = KvStore::new();
        kv.set_ex("r1", b"x".to_vec(), 10.0, 0.0);
        kv.set_ex("r2", b"y".to_vec(), 100.0, 0.0);
        kv.set("keep", b"z".to_vec());
        assert!(kv.get_at("r1", 5.0).is_some());
        assert_eq!(kv.purge_expired(50.0), 1); // r1 expired at t=10; r2 alive
        assert!(kv.get_at("r2", 50.0).is_some());
        assert!(kv.get("keep").is_some());
    }

    #[test]
    fn lpush_returns_to_front() {
        let kv = KvStore::new();
        kv.rpush("q", b"b".to_vec());
        kv.lpush("q", b"a".to_vec());
        assert_eq!(kv.lpop("q"), Some(b"a".into()));
        assert_eq!(kv.lpop("q"), Some(b"b".into()));
    }

    #[test]
    fn lpop_n_batches() {
        let kv = KvStore::new();
        for i in 0..10u8 {
            kv.rpush("q", vec![i]);
        }
        let got = kv.lpop_n("q", 4);
        let raw: Vec<Vec<u8>> = got.iter().map(|b| b.to_vec()).collect();
        assert_eq!(raw, vec![vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(kv.llen("q"), 6);
        assert_eq!(kv.lpop_n("q", 100).len(), 6);
        assert_eq!(kv.lpop_n("q", 1).len(), 0);
    }

    #[test]
    fn blpop_wakes_on_push() {
        let kv = KvStore::new();
        let kv2 = kv.clone();
        let h = thread::spawn(move || kv2.blpop("q", Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        kv.rpush("q", b"wake".to_vec());
        assert_eq!(h.join().unwrap(), Some(b"wake".into()));
    }

    #[test]
    fn blpop_n_wakes_on_push_and_batches() {
        let kv = KvStore::new();
        let kv2 = kv.clone();
        let t0 = Instant::now();
        let h = thread::spawn(move || kv2.blpop_n("q", 8, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        for i in 0..3u8 {
            kv.rpush("q", vec![i]);
        }
        let got = h.join().unwrap();
        // Wakes on the first push — well before the 5 s timeout — and
        // drains what is available without waiting for a full batch.
        assert!(!got.is_empty() && got.len() <= 3);
        assert!(t0.elapsed() < Duration::from_secs(4));
        assert_eq!(got[0].to_vec(), vec![0]);
    }

    #[test]
    fn blpop_times_out() {
        let kv = KvStore::new();
        let t0 = std::time::Instant::now();
        assert_eq!(kv.blpop("q", Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn rpush_many_appends_in_order_with_one_notify() {
        let kv = KvStore::new();
        let n = Arc::new(Notify::new());
        kv.add_watch("q", n.clone());
        kv.rpush("q", b"a".to_vec());
        let before = n.notify_count();
        let batch = vec![b"b".to_vec().into(), b"c".to_vec().into(), b"d".to_vec().into()];
        let len = kv.rpush_many("q", batch);
        assert_eq!(len, 4);
        assert_eq!(n.notify_count(), before + 1, "one notify per flush, not per frame");
        let raw: Vec<Vec<u8>> = kv.lpop_n("q", 10).iter().map(|b| b.to_vec()).collect();
        assert_eq!(raw, vec![b"a".to_vec(), b"b".to_vec(), b"c".to_vec(), b"d".to_vec()]);
        // Empty flush: no wakeup at all.
        let before = n.notify_count();
        assert_eq!(kv.rpush_many("q", Vec::new()), 0);
        assert_eq!(n.notify_count(), before);
    }

    #[test]
    fn rpush_many_wakes_blocked_popper() {
        let kv = KvStore::new();
        let kv2 = kv.clone();
        let h = thread::spawn(move || kv2.blpop_n("q", 8, Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        kv.rpush_many("q", vec![b"x".to_vec().into(), b"y".to_vec().into()]);
        let got = h.join().unwrap();
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn watch_notified_on_push() {
        let kv = KvStore::new();
        let n = Arc::new(Notify::new());
        kv.add_watch("q", n.clone());
        let seen = n.epoch();
        kv.rpush("q", b"x".to_vec());
        assert_ne!(n.epoch(), seen, "push must signal the watch");
        // Dropped watches are pruned and do not panic later pushes.
        drop(n);
        kv.rpush("q", b"y".to_vec());
        kv.lpush("q", b"z".to_vec());
    }

    #[test]
    fn counters() {
        let kv = KvStore::new();
        assert_eq!(kv.incr("c"), 1);
        assert_eq!(kv.incr("c"), 2);
        assert_eq!(kv.counter("c"), 2);
        assert_eq!(kv.counter("other"), 0);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let kv = KvStore::new();
        let n_prod = 4;
        let per = 500;
        let mut handles = Vec::new();
        for p in 0..n_prod {
            let kv = kv.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    kv.rpush("q", format!("{p}:{i}").into_bytes());
                }
            }));
        }
        let consumed = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        for _ in 0..3 {
            let kv = kv.clone();
            let consumed = consumed.clone();
            handles.push(thread::spawn(move || {
                while kv.blpop("q", Duration::from_millis(100)).is_some() {
                    consumed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            consumed.load(std::sync::atomic::Ordering::Relaxed) + kv.llen("q"),
            n_prod * per
        );
    }

    /// Multi-producer / multi-consumer stress across shards: every item
    /// pushed to any of 8 keys is consumed exactly once, and per-key
    /// order is preserved (each key has one consumer).
    #[test]
    fn sharded_mpmc_no_loss_no_dup_fifo() {
        let kv = KvStore::new();
        let n_keys = 8usize;
        let n_prod = 4usize;
        let per = 400usize; // per producer per key
        let mut handles = Vec::new();
        for p in 0..n_prod {
            let kv = kv.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    for k in 0..n_keys {
                        // Encode (producer, seq) so consumers can check
                        // per-producer order within each key.
                        let mut v = (p as u32).to_le_bytes().to_vec();
                        v.extend((i as u32).to_le_bytes());
                        kv.rpush(&format!("q{k}"), v);
                    }
                }
            }));
        }
        let mut consumers = Vec::new();
        for k in 0..n_keys {
            let kv = kv.clone();
            consumers.push(thread::spawn(move || {
                let key = format!("q{k}");
                let want = n_prod * per;
                let mut got = 0usize;
                let mut last_seq = vec![-1i64; n_prod];
                while got < want {
                    for item in kv.blpop_n(&key, 64, Duration::from_secs(10)) {
                        let p = u32::from_le_bytes(item[0..4].try_into().unwrap()) as usize;
                        let i = i64::from(u32::from_le_bytes(item[4..8].try_into().unwrap()));
                        assert!(
                            i > last_seq[p],
                            "per-key FIFO violated for producer {p}: {i} after {}",
                            last_seq[p]
                        );
                        last_seq[p] = i;
                        got += 1;
                    }
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, n_keys * n_prod * per, "no item lost or duplicated");
        for k in 0..n_keys {
            assert_eq!(kv.llen(&format!("q{k}")), 0);
        }
    }
}
