//! The key-value core: strings + TTL, hashes, lists, counters.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::common::time::Time;

#[derive(Default)]
struct Shard {
    strings: HashMap<String, (Vec<u8>, Option<Time>)>,
    hashes: HashMap<String, HashMap<String, Vec<u8>>>,
    lists: HashMap<String, VecDeque<Vec<u8>>>,
    counters: HashMap<String, i64>,
}

/// An in-process Redis-subset store. Cheap to clone (Arc inside); all
/// operations are linearizable under one mutex per store — funcX's Redis
/// is single-threaded per shard too, so this matches the consistency
/// model the paper's queues rely on.
#[derive(Clone)]
pub struct KvStore {
    inner: Arc<(Mutex<Shard>, Condvar)>,
}

impl Default for KvStore {
    fn default() -> Self {
        Self::new()
    }
}

impl KvStore {
    pub fn new() -> Self {
        KvStore { inner: Arc::new((Mutex::new(Shard::default()), Condvar::new())) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Shard> {
        self.inner.0.lock().expect("kv store poisoned")
    }

    // ---- strings ---------------------------------------------------------

    /// SET key value (no expiry).
    pub fn set(&self, key: &str, value: Vec<u8>) {
        self.lock().strings.insert(key.to_string(), (value, None));
    }

    /// SETEX: set with a TTL relative to `now` (caller supplies the clock
    /// reading so the simulator can drive expiry under virtual time).
    pub fn set_ex(&self, key: &str, value: Vec<u8>, ttl_s: f64, now: Time) {
        self.lock().strings.insert(key.to_string(), (value, Some(now + ttl_s)));
    }

    /// GET at an explicit time (TTL-aware).
    pub fn get_at(&self, key: &str, now: Time) -> Option<Vec<u8>> {
        let mut g = self.lock();
        match g.strings.get(key) {
            Some((_, Some(exp))) if now >= *exp => {
                g.strings.remove(key);
                None
            }
            Some((v, _)) => Some(v.clone()),
            None => None,
        }
    }

    /// GET ignoring TTL bookkeeping (keys set without expiry).
    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        self.get_at(key, 0.0)
    }

    /// DEL; returns whether the key existed.
    pub fn del(&self, key: &str) -> bool {
        let mut g = self.lock();
        g.strings.remove(key).is_some()
            | g.hashes.remove(key).is_some()
            | g.lists.remove(key).is_some()
    }

    /// Purge every expired string key (the service's periodic result
    /// purge; §4.1). Returns the number purged.
    pub fn purge_expired(&self, now: Time) -> usize {
        let mut g = self.lock();
        let before = g.strings.len();
        g.strings.retain(|_, (_, exp)| exp.map_or(true, |e| now < e));
        before - g.strings.len()
    }

    // ---- hashes ----------------------------------------------------------

    pub fn hset(&self, key: &str, field: &str, value: Vec<u8>) {
        self.lock()
            .hashes
            .entry(key.to_string())
            .or_default()
            .insert(field.to_string(), value);
    }

    pub fn hget(&self, key: &str, field: &str) -> Option<Vec<u8>> {
        self.lock().hashes.get(key).and_then(|h| h.get(field).cloned())
    }

    pub fn hdel(&self, key: &str, field: &str) -> bool {
        self.lock()
            .hashes
            .get_mut(key)
            .map(|h| h.remove(field).is_some())
            .unwrap_or(false)
    }

    pub fn hlen(&self, key: &str) -> usize {
        self.lock().hashes.get(key).map(|h| h.len()).unwrap_or(0)
    }

    pub fn hkeys(&self, key: &str) -> Vec<String> {
        self.lock()
            .hashes
            .get(key)
            .map(|h| h.keys().cloned().collect())
            .unwrap_or_default()
    }

    // ---- lists (queues) ---------------------------------------------------

    /// RPUSH: append to the tail; wakes blocked poppers.
    pub fn rpush(&self, key: &str, value: Vec<u8>) -> usize {
        let mut g = self.lock();
        let l = g.lists.entry(key.to_string()).or_default();
        l.push_back(value);
        let n = l.len();
        drop(g);
        self.inner.1.notify_all();
        n
    }

    /// LPUSH: prepend to the head (used to *return* undelivered tasks to
    /// the front of the queue on agent loss; §4.1).
    pub fn lpush(&self, key: &str, value: Vec<u8>) -> usize {
        let mut g = self.lock();
        let l = g.lists.entry(key.to_string()).or_default();
        l.push_front(value);
        let n = l.len();
        drop(g);
        self.inner.1.notify_all();
        n
    }

    /// LPOP: pop from the head.
    pub fn lpop(&self, key: &str) -> Option<Vec<u8>> {
        self.lock().lists.get_mut(key).and_then(|l| l.pop_front())
    }

    /// Pop up to `n` items (pipelined LPOP — the batching fast path).
    pub fn lpop_n(&self, key: &str, n: usize) -> Vec<Vec<u8>> {
        let mut g = self.lock();
        match g.lists.get_mut(key) {
            Some(l) => {
                let take = n.min(l.len());
                l.drain(..take).collect()
            }
            None => Vec::new(),
        }
    }

    /// BLPOP: block until an item arrives or `timeout` elapses.
    pub fn blpop(&self, key: &str, timeout: Duration) -> Option<Vec<u8>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.lock();
        loop {
            if let Some(v) = g.lists.get_mut(key).and_then(|l| l.pop_front()) {
                return Some(v);
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return None;
            }
            let (guard, timed_out) = self
                .inner
                .1
                .wait_timeout(g, remaining)
                .expect("kv store poisoned");
            g = guard;
            if timed_out.timed_out() {
                // Re-check once after timeout to avoid a lost-wakeup race.
                return g.lists.get_mut(key).and_then(|l| l.pop_front());
            }
        }
    }

    pub fn llen(&self, key: &str) -> usize {
        self.lock().lists.get(key).map(|l| l.len()).unwrap_or(0)
    }

    // ---- counters ----------------------------------------------------------

    pub fn incr(&self, key: &str) -> i64 {
        let mut g = self.lock();
        let c = g.counters.entry(key.to_string()).or_insert(0);
        *c += 1;
        *c
    }

    pub fn counter(&self, key: &str) -> i64 {
        *self.lock().counters.get(key).unwrap_or(&0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn string_set_get_del() {
        let kv = KvStore::new();
        kv.set("a", b"1".to_vec());
        assert_eq!(kv.get("a"), Some(b"1".to_vec()));
        assert!(kv.del("a"));
        assert_eq!(kv.get("a"), None);
        assert!(!kv.del("a"));
    }

    #[test]
    fn ttl_and_purge() {
        let kv = KvStore::new();
        kv.set_ex("r1", b"x".to_vec(), 10.0, 0.0);
        kv.set_ex("r2", b"y".to_vec(), 100.0, 0.0);
        kv.set("keep", b"z".to_vec());
        assert!(kv.get_at("r1", 5.0).is_some());
        assert_eq!(kv.purge_expired(50.0), 1); // r1 expired at t=10; r2 alive
        assert!(kv.get_at("r2", 50.0).is_some());
        assert!(kv.get("keep").is_some());
    }

    #[test]
    fn lpush_returns_to_front() {
        let kv = KvStore::new();
        kv.rpush("q", b"b".to_vec());
        kv.lpush("q", b"a".to_vec());
        assert_eq!(kv.lpop("q"), Some(b"a".to_vec()));
        assert_eq!(kv.lpop("q"), Some(b"b".to_vec()));
    }

    #[test]
    fn lpop_n_batches() {
        let kv = KvStore::new();
        for i in 0..10u8 {
            kv.rpush("q", vec![i]);
        }
        let got = kv.lpop_n("q", 4);
        assert_eq!(got, vec![vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(kv.llen("q"), 6);
        assert_eq!(kv.lpop_n("q", 100).len(), 6);
        assert_eq!(kv.lpop_n("q", 1).len(), 0);
    }

    #[test]
    fn blpop_wakes_on_push() {
        let kv = KvStore::new();
        let kv2 = kv.clone();
        let h = thread::spawn(move || kv2.blpop("q", Duration::from_secs(5)));
        thread::sleep(Duration::from_millis(20));
        kv.rpush("q", b"wake".to_vec());
        assert_eq!(h.join().unwrap(), Some(b"wake".to_vec()));
    }

    #[test]
    fn blpop_times_out() {
        let kv = KvStore::new();
        let t0 = std::time::Instant::now();
        assert_eq!(kv.blpop("q", Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn counters() {
        let kv = KvStore::new();
        assert_eq!(kv.incr("c"), 1);
        assert_eq!(kv.incr("c"), 2);
        assert_eq!(kv.counter("c"), 2);
        assert_eq!(kv.counter("other"), 0);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let kv = KvStore::new();
        let n_prod = 4;
        let per = 500;
        let mut handles = Vec::new();
        for p in 0..n_prod {
            let kv = kv.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    kv.rpush("q", format!("{p}:{i}").into_bytes());
                }
            }));
        }
        let consumed = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        for _ in 0..3 {
            let kv = kv.clone();
            let consumed = consumed.clone();
            handles.push(thread::spawn(move || {
                while kv.blpop("q", Duration::from_millis(100)).is_some() {
                    consumed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            consumed.load(std::sync::atomic::Ordering::Relaxed) + kv.llen("q"),
            n_prod * per
        );
    }
}
