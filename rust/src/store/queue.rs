//! Typed task/result queues over the KV store's lists.
//!
//! Each registered endpoint gets a Redis task queue and a result queue
//! (§4.1, "implemented using Redis Lists"). Tasks are serialized into the
//! list; acknowledgement semantics live a layer up (the forwarder caches
//! in-flight tasks until the agent acks — §4.1 "tasks are cached at each
//! layer and only removed when downstream layers have acknowledged").

use std::sync::Arc;
use std::time::Duration;

use crate::common::error::Result;
use crate::common::sync::Notify;
use crate::serialize::Wire;
use crate::store::KvStore;

/// A typed FIFO queue stored as a Redis-style list.
#[derive(Clone)]
pub struct TaskQueue<T> {
    kv: KvStore,
    key: String,
    _marker: std::marker::PhantomData<fn() -> T>,
}

impl<T: Wire> TaskQueue<T> {
    pub fn new(kv: KvStore, key: impl Into<String>) -> Self {
        TaskQueue { kv, key: key.into(), _marker: std::marker::PhantomData }
    }

    pub fn key(&self) -> &str {
        &self.key
    }

    /// Append to the tail (normal enqueue). The serialized frame is a
    /// shared [`crate::serialize::Buffer`]; the store keeps a refcounted
    /// handle rather than copying the bytes in.
    pub fn push(&self, item: &T) -> Result<usize> {
        Ok(self.kv.rpush(&self.key, item.to_buffer()))
    }

    /// Append a whole batch under one lock acquisition with ONE watcher
    /// wakeup for the flush ([`KvStore::rpush_many`] — producer-side
    /// watch coalescing): the batch-submit path enqueues B tasks for the
    /// cost of a single notify.
    pub fn push_all(&self, items: &[T]) -> Result<usize> {
        Ok(self.kv.rpush_many(&self.key, items.iter().map(Wire::to_buffer).collect()))
    }

    /// Return an item to the *front* (re-dispatch after agent loss; §4.1).
    pub fn push_front(&self, item: &T) -> Result<usize> {
        Ok(self.kv.lpush(&self.key, item.to_buffer()))
    }

    /// Non-blocking pop. Decoding borrows the popped frame in place;
    /// payload-carrying types come back holding zero-copy views into it.
    pub fn pop(&self) -> Result<Option<T>> {
        match self.kv.lpop(&self.key) {
            Some(frame) => Ok(Some(T::from_buffer(&frame)?)),
            None => Ok(None),
        }
    }

    /// Pop up to `n` items in one call (internal batching; §4.6).
    pub fn pop_n(&self, n: usize) -> Result<Vec<T>> {
        self.kv.lpop_n(&self.key, n).iter().map(T::from_buffer).collect()
    }

    /// Blocking pop with timeout (the forwarder's listen loop).
    pub fn pop_blocking(&self, timeout: Duration) -> Result<Option<T>> {
        match self.kv.blpop(&self.key, timeout) {
            Some(frame) => Ok(Some(T::from_buffer(&frame)?)),
            None => Ok(None),
        }
    }

    /// Blocking batched pop: wait (bounded) until items arrive, then
    /// drain up to `max` in one store op. Empty on timeout.
    pub fn pop_blocking_n(&self, max: usize, timeout: Duration) -> Result<Vec<T>> {
        self.kv.blpop_n(&self.key, max, timeout).iter().map(T::from_buffer).collect()
    }

    /// Signal `notify` whenever this queue receives a push (weakly held;
    /// see [`KvStore::add_watch`]).
    pub fn watch(&self, notify: Arc<Notify>) {
        self.kv.add_watch(&self.key, notify);
    }

    pub fn len(&self) -> usize {
        self.kv.llen(&self.key)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::*;
    use crate::common::task::{Payload, Task};
    use crate::serialize::Buffer;

    fn mk_task() -> Task {
        Task::new(
            FunctionId::new(),
            EndpointId::new(),
            UserId::new(),
            None,
            Payload::Noop,
            Buffer::empty(),
        )
    }

    #[test]
    fn typed_roundtrip() {
        let kv = KvStore::new();
        let q: TaskQueue<Task> = TaskQueue::new(kv, "ep:tasks");
        let t = mk_task();
        q.push(&t).unwrap();
        let back = q.pop().unwrap().unwrap();
        assert_eq!(back.id, t.id);
        assert!(q.pop().unwrap().is_none());
    }

    #[test]
    fn front_requeue_order() {
        let kv = KvStore::new();
        let q: TaskQueue<u32> = TaskQueue::new(kv, "q");
        q.push(&1).unwrap();
        q.push(&2).unwrap();
        let first = q.pop().unwrap().unwrap();
        assert_eq!(first, 1);
        q.push_front(&first).unwrap(); // simulate agent loss re-queue
        assert_eq!(q.pop().unwrap().unwrap(), 1);
        assert_eq!(q.pop().unwrap().unwrap(), 2);
    }

    #[test]
    fn pop_n_preserves_order() {
        let kv = KvStore::new();
        let q: TaskQueue<u32> = TaskQueue::new(kv, "q");
        for i in 0..10 {
            q.push(&i).unwrap();
        }
        assert_eq!(q.pop_n(4).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn push_all_preserves_order_and_coalesces_wakeups() {
        let kv = KvStore::new();
        let q: TaskQueue<u32> = TaskQueue::new(kv, "q");
        let n = std::sync::Arc::new(crate::common::sync::Notify::new());
        q.watch(n.clone());
        let before = n.notify_count();
        q.push_all(&[1, 2, 3, 4]).unwrap();
        assert_eq!(n.notify_count(), before + 1, "one notify for the whole batch");
        assert_eq!(q.pop_n(8).unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn queues_isolated_by_key() {
        let kv = KvStore::new();
        let a: TaskQueue<u32> = TaskQueue::new(kv.clone(), "ep-a:tasks");
        let b: TaskQueue<u32> = TaskQueue::new(kv, "ep-b:tasks");
        a.push(&1).unwrap();
        assert!(b.pop().unwrap().is_none());
        assert_eq!(a.pop().unwrap(), Some(1));
    }

    #[test]
    fn blocking_batched_pop_wakes_on_push() {
        let kv = KvStore::new();
        let q: TaskQueue<u32> = TaskQueue::new(kv.clone(), "q");
        let q2 = q.clone();
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || q2.pop_blocking_n(64, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        q.push(&1).unwrap();
        q.push(&2).unwrap();
        let got = h.join().unwrap().unwrap();
        assert!(!got.is_empty(), "pop_blocking_n must wake on push");
        assert_eq!(got[0], 1);
        assert!(t0.elapsed() < Duration::from_secs(4), "woke by push, not timeout");
    }

    #[test]
    fn watch_signals_on_queue_push() {
        let kv = KvStore::new();
        let q: TaskQueue<u32> = TaskQueue::new(kv, "q");
        let n = std::sync::Arc::new(crate::common::sync::Notify::new());
        q.watch(n.clone());
        let seen = n.epoch();
        q.push(&7).unwrap();
        assert_ne!(n.epoch(), seen);
    }

    #[test]
    fn blocking_pop_sees_push() {
        let kv = KvStore::new();
        let q: TaskQueue<u32> = TaskQueue::new(kv.clone(), "q");
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_blocking(Duration::from_secs(2)));
        std::thread::sleep(Duration::from_millis(10));
        q.push(&9).unwrap();
        assert_eq!(h.join().unwrap().unwrap(), Some(9));
    }
}
