//! Redis-subset in-memory store — the broker substrate (§4.1).
//!
//! funcX stores serialized functions and tasks in an AWS ElastiCache
//! Redis hashset and implements its hierarchical task/result queues as
//! Redis Lists. We implement the subset funcX uses, in-process:
//!
//! * strings with TTL ([`KvStore::set`], [`KvStore::get`], expiry purge),
//! * hashes ([`KvStore::hset`], [`KvStore::hget`]),
//! * lists used as queues ([`KvStore::rpush`], [`KvStore::lpop`],
//!   blocking pop with timeout — Redis `BLPOP` — and the batched
//!   [`KvStore::blpop_n`]),
//! * counters ([`KvStore::incr`]).
//!
//! The store is **lock-striped**: keys hash onto independent shards
//! (each its own `Mutex + Condvar`), so the forwarder fleet's
//! per-endpoint queues never serialize behind one global lock, while
//! every single-key operation remains linearizable (see [`kv`] module
//! docs). Consumers block on push-driven wakeups — shard condvars for
//! `BLPOP`, or a registered [`crate::common::sync::Notify`] watch
//! ([`KvStore::add_watch`]) for loops that multiplex several wake
//! sources.
//!
//! The same type backs (a) the service's task brokering and (b) the
//! endpoint-local in-memory data store used for intra-endpoint data
//! management (§5.2, Tables 1–2).

mod kv;
mod queue;

pub use kv::KvStore;
pub use queue::TaskQueue;

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn list_fifo_invariant() {
        // FIFO: a list fed by rpush and drained by lpop yields exactly the
        // pushed sequence (queue semantics the task broker depends on).
        check("list-fifo", 100, |g| {
            let kv = KvStore::new();
            let items = g.vec(0..200, |g| g.u64());
            for i in &items {
                kv.rpush("q", i.to_le_bytes().to_vec());
            }
            let mut out = Vec::new();
            while let Some(b) = kv.lpop("q") {
                out.push(u64::from_le_bytes(b.as_slice().try_into().unwrap()));
            }
            assert_eq!(out, items);
        });
    }

    #[test]
    fn list_len_conserved() {
        // llen always equals pushes minus pops.
        check("list-len", 100, |g| {
            let kv = KvStore::new();
            let pushes = g.usize(0, 100);
            let pops = g.usize(0, 120);
            for i in 0..pushes {
                kv.rpush("q", vec![i as u8]);
            }
            let mut popped = 0;
            for _ in 0..pops {
                if kv.lpop("q").is_some() {
                    popped += 1;
                }
            }
            assert_eq!(popped, pops.min(pushes));
            assert_eq!(kv.llen("q"), pushes - popped);
        });
    }

    #[test]
    fn mpmc_conserves_items_across_shards() {
        // Concurrent producers/consumers over many keys (which spread
        // across shards): nothing lost, nothing duplicated, and each
        // key's drain order is the per-key push order.
        check("shard-mpmc", 20, |g| {
            let kv = KvStore::new();
            let n_keys = g.usize(2, 6);
            let per_key = g.usize(1, 120);
            let mut producers = Vec::new();
            for k in 0..n_keys {
                let kv = kv.clone();
                producers.push(std::thread::spawn(move || {
                    for i in 0..per_key {
                        kv.rpush(&format!("k{k}"), (i as u64).to_le_bytes().to_vec());
                    }
                }));
            }
            let mut consumers = Vec::new();
            for k in 0..n_keys {
                let kv = kv.clone();
                consumers.push(std::thread::spawn(move || {
                    let key = format!("k{k}");
                    let mut seen = 0u64;
                    while (seen as usize) < per_key {
                        for item in
                            kv.blpop_n(&key, 16, std::time::Duration::from_secs(5))
                        {
                            let v =
                                u64::from_le_bytes(item.as_slice().try_into().unwrap());
                            assert_eq!(v, seen, "per-key FIFO broken on {key}");
                            seen += 1;
                        }
                    }
                    seen
                }));
            }
            for p in producers {
                p.join().unwrap();
            }
            let total: u64 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
            assert_eq!(total as usize, n_keys * per_key);
        });
    }

    #[test]
    fn hash_last_write_wins() {
        check("hash-lww", 100, |g| {
            let kv = KvStore::new();
            let mut oracle = std::collections::HashMap::new();
            let n = g.usize(1, 40);
            for _ in 0..n {
                let field = ["a", "b", "c", "d"][g.usize(0, 4)].to_string();
                let val = g.bytes(16);
                kv.hset("h", &field, val.clone());
                oracle.insert(field, val);
            }
            for (field, val) in &oracle {
                assert_eq!(kv.hget("h", field).as_deref(), Some(val.as_slice()));
            }
            assert_eq!(kv.hlen("h"), oracle.len());
        });
    }

    #[test]
    fn ttl_expiry_boundary() {
        // Keys readable strictly before expiry, gone at/after.
        check("ttl-expiry", 200, |g| {
            let kv = KvStore::new();
            let ttl = g.f64(0.1, 100.0);
            let probe = g.f64(0.0, 200.0);
            kv.set_ex("k", b"v".to_vec(), ttl, 0.0);
            let got = kv.get_at("k", probe);
            if probe < ttl {
                assert!(got.is_some());
            } else {
                assert!(got.is_none());
            }
        });
    }

    #[test]
    fn mixed_push_pop_front_back() {
        // Oracle comparison against VecDeque under a random op sequence.
        check("deque-oracle", 100, |g| {
            let kv = KvStore::new();
            let mut oracle = std::collections::VecDeque::new();
            let ops = g.usize(1, 120);
            for _ in 0..ops {
                match g.usize(0, 3) {
                    0 => {
                        let v = g.bytes(8);
                        kv.rpush("q", v.clone());
                        oracle.push_back(v);
                    }
                    1 => {
                        let v = g.bytes(8);
                        kv.lpush("q", v.clone());
                        oracle.push_front(v);
                    }
                    _ => {
                        assert_eq!(kv.lpop("q").map(|b| b.to_vec()), oracle.pop_front());
                    }
                }
                assert_eq!(kv.llen("q"), oracle.len());
            }
        });
    }
}
