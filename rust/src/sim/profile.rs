//! Per-testbed simulator cost profiles, calibrated from the paper's own
//! measurements (see mod-level docs for the derivations).

use crate::containers::{ContainerTech, StartCostModel, SystemProfile, TABLE3_MODELS};

/// The simulator's cost parameters for one testbed.
#[derive(Clone, Copy, Debug)]
pub struct SimProfile {
    pub system: SystemProfile,
    pub tech: ContainerTech,
    /// Serial agent dispatch cost per task, seconds (1 / peak throughput).
    pub dispatch_s: f64,
    /// Per-task worker-side overhead (deserialize + spawn + result),
    /// seconds. KNL cores are slow (§6.1's third argument).
    pub worker_overhead_s: f64,
    /// Request round-trip paid *per task* when internal batching is off.
    pub rtt_s: f64,
    /// Containers (worker slots) per node.
    pub workers_per_node: usize,
    /// Serial agent-link bandwidth for *inline* payload bytes, bytes/s.
    /// Both directions share it: the dispatch loop ships each inline
    /// input downstream, and each completed inline result occupies the
    /// same wire upstream before the next dispatch proceeds.
    pub wire_bps: f64,
    /// Payloads strictly above this size travel as a fixed-size
    /// `DataRef` frame instead of inline bytes — inputs on dispatch
    /// (§5 pass-by-reference, mirroring `ServiceConfig::
    /// max_payload_bytes` and its `len > cap` offload rule) and outputs
    /// on the return path (§5 result offload, mirroring
    /// `EndpointConfig::max_result_bytes`).
    pub ref_threshold_bytes: u64,
    /// Intra-endpoint data-store bandwidth, bytes/s — what a worker
    /// pays once to fetch a by-ref input from the in-memory store
    /// (§5.2, Fig. 5's fastest adopted channel); by-ref outputs land in
    /// the same store, so a ref-forwarded chain stage pays this instead
    /// of two wire crossings (`SimEndpoint::run_chain`).
    pub store_bps: f64,
}

impl SimProfile {
    /// ANL Theta: 64 Singularity containers/node (§7.2); peak 1694 req/s
    /// (§7.2.3) ⇒ dispatch 0.59 ms; no-op strong scaling flattens at 256
    /// containers (Fig. 4a) ⇒ worker overhead ≈ 256 × 0.59 ms ≈ 150 ms.
    pub fn theta() -> Self {
        SimProfile {
            system: SystemProfile::Theta,
            tech: ContainerTech::Singularity,
            dispatch_s: 1.0 / 1694.0,
            worker_overhead_s: 0.150,
            rtt_s: 0.0112, // §7.5: 118 s / 10 000 unbatched no-ops
            workers_per_node: 64,
            wire_bps: 1.25e9,                      // 10 Gb/s service link
            ref_threshold_bytes: 10 * 1024 * 1024, // §5.1 data cap
            store_bps: 1.0e10,                     // in-memory store read
        }
    }

    /// NERSC Cori: 256 Shifter containers/node (4 hw threads/core);
    /// peak 1466 req/s ⇒ dispatch 0.68 ms.
    pub fn cori() -> Self {
        SimProfile {
            system: SystemProfile::Cori,
            tech: ContainerTech::Shifter,
            dispatch_s: 1.0 / 1466.0,
            worker_overhead_s: 0.175,
            rtt_s: 0.0125,
            workers_per_node: 256,
            wire_bps: 1.25e9,
            ref_threshold_bytes: 10 * 1024 * 1024,
            store_bps: 1.0e10,
        }
    }

    /// A fast local/cloud profile (for ablations).
    pub fn local() -> Self {
        SimProfile {
            system: SystemProfile::Local,
            tech: ContainerTech::Docker,
            dispatch_s: 0.0002,
            worker_overhead_s: 0.002,
            rtt_s: 0.001,
            workers_per_node: 8,
            wire_bps: 1.25e10, // cloud-local 100 Gb/s
            ref_threshold_bytes: 10 * 1024 * 1024,
            store_bps: 2.0e10,
        }
    }

    pub fn start_model(&self) -> StartCostModel {
        TABLE3_MODELS.lookup(self.system, self.tech)
    }

    /// Peak sustainable agent throughput under this profile (§7.2.3).
    pub fn peak_throughput(&self) -> f64 {
        1.0 / self.dispatch_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_matches_paper_numbers() {
        let theta = SimProfile::theta();
        assert!((theta.peak_throughput() - 1694.0).abs() < 1.0);
        assert_eq!(theta.workers_per_node, 64);
        let cori = SimProfile::cori();
        assert!((cori.peak_throughput() - 1466.0).abs() < 1.0);
        assert_eq!(cori.workers_per_node, 256);
    }

    #[test]
    fn strong_scaling_knee_near_256() {
        // N* = w/d should land near the paper's observed 256-container knee.
        let t = SimProfile::theta();
        let knee = t.worker_overhead_s / t.dispatch_s;
        assert!((200.0..320.0).contains(&knee), "knee at {knee}");
    }

    #[test]
    fn start_models_resolve() {
        assert!(SimProfile::theta().start_model().mean() > 9.0);
        assert!(SimProfile::cori().start_model().mean() > 7.0);
        assert!(SimProfile::local().start_model().mean() < 2.0);
    }
}
