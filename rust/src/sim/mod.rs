//! The discrete-event simulator — the scale substrate for the paper's
//! HPC experiments (Fig. 4's 131 072 containers, Figs. 6–7's routing
//! comparison, §7.5's batching ablation).
//!
//! The simulator drives the *same* policy objects as the live engine —
//! [`crate::routing::Scheduler`], [`crate::containers::WarmPool`],
//! [`crate::provider::Strategy`], [`crate::batching::Prefetcher`] —
//! under virtual time, with cost models calibrated to the paper's own
//! measurements:
//!
//! * **agent dispatch cost** `d` per task: the serial brokering cost at
//!   the agent. Calibrated from §7.2.3's peak throughput (1694 req/s on
//!   Theta ⇒ d ≈ 0.59 ms; 1466 req/s on Cori ⇒ d ≈ 0.68 ms).
//! * **worker task overhead** `w` per task: deserialize + dispatch +
//!   result path on a slow KNL core. Calibrated from Fig. 4(a): strong
//!   scaling of no-ops flattens at N* ≈ w/d ≈ 256 containers ⇒ w ≈ 150 ms.
//! * **cold container starts**: Table 3 distributions (see
//!   [`crate::containers::StartCostModel`]).
//! * **batching off**: each dispatch pays a request round-trip
//!   (§7.5: 10 000 no-ops, 6.7 s batched vs 118 s unbatched ⇒ RTT ≈ 11 ms).

mod endpoint;
mod events;
mod fleet;
mod profile;

pub use endpoint::{SimEndpoint, SimReport, SimTask};
pub use events::{Event, EventQueue};
pub use fleet::{FleetReport, SimFleet};
pub use profile::SimProfile;
