//! The simulated endpoint: agent + managers + workers under virtual
//! time, driving the *live* policy objects ([`Scheduler`], [`WarmPool`]).
//!
//! Model (calibrated in [`super::profile`]):
//! * the agent is a serial dispatcher: each routed task costs
//!   `dispatch_s` (plus `rtt_s` when internal batching is disabled);
//! * routing runs the real [`Scheduler`] over an incrementally-maintained
//!   [`RoutingTable`] (O(log managers) per warming-aware route,
//!   O(types·log managers) per slot-change update);
//! * a routed task immediately occupies a container slot in the target
//!   manager's real [`WarmPool`]; cold starts sample the Table-3 model;
//! * the task completes `cold + worker_overhead + duration` later,
//!   releasing the slot and waking the agent if it stalled on capacity.

use std::collections::VecDeque;

use crate::common::ids::{ContainerId, ManagerId};
use crate::common::rng::Rng;
use crate::common::time::Time;
use crate::containers::WarmPool;
use crate::routing::{ManagerView, RoutingTable, Scheduler};
use crate::sim::events::{Event, EventQueue};
use crate::sim::profile::SimProfile;

/// Wire size of a `DataRef` frame (owner + epoch + key + size +
/// checksum) — what a by-ref task ships through the serial agent link
/// instead of its payload bytes.
const REF_FRAME_BYTES: u64 = 128;

/// One simulated task.
#[derive(Clone, Copy, Debug)]
pub struct SimTask {
    /// Container type required (None = bare worker env).
    pub container: Option<ContainerId>,
    /// Function execution time (0 = no-op, 1 = sleep 1s, 60 = stress).
    pub duration_s: f64,
    /// Serialized input size. Inputs at or below the profile's
    /// `ref_threshold_bytes` ship inline through the serial agent link;
    /// above it the task dispatches a fixed-size `DataRef` frame and
    /// the worker fetches the payload from the intra-endpoint store
    /// once (§5 pass-by-reference).
    pub input_bytes: u64,
    /// Serialized output size. Outputs above the profile's
    /// `ref_threshold_bytes` return as a fixed-size `DataRef` frame over
    /// the serial wire — the bytes stay in the endpoint store (§5
    /// result offload); at or below it the full output occupies the
    /// upstream wire.
    pub output_bytes: u64,
}

impl SimTask {
    pub fn noop() -> Self {
        SimTask { container: None, duration_s: 0.0, input_bytes: 0, output_bytes: 0 }
    }

    pub fn sleep(s: f64) -> Self {
        SimTask { container: None, duration_s: s, input_bytes: 0, output_bytes: 0 }
    }

    pub fn with_container(c: ContainerId, duration_s: f64) -> Self {
        SimTask { container: Some(c), duration_s, input_bytes: 0, output_bytes: 0 }
    }

    /// Set the serialized input size carried by this task.
    pub fn with_input_bytes(mut self, n: u64) -> Self {
        self.input_bytes = n;
        self
    }

    /// Set the serialized output size this task produces.
    pub fn with_output_bytes(mut self, n: u64) -> Self {
        self.output_bytes = n;
        self
    }
}

/// Results of a simulation run.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Total completion time of the batch (makespan), seconds.
    pub completion_s: f64,
    pub tasks: usize,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub evictions: u64,
    /// Mean per-task latency (submit→done).
    pub mean_latency_s: f64,
    /// Full distribution of per-task completion times (interpolated
    /// percentiles from [`crate::metrics::summarize`]); the mean above
    /// is kept for call-site compatibility.
    pub latency: crate::metrics::Summary,
    /// Achieved throughput, tasks/s.
    pub throughput: f64,
    /// Replica copies pushed for by-ref outputs (§5 survivability;
    /// `replication × by-ref results`). Replication is asynchronous —
    /// it never extends the makespan — so the cost is reported as
    /// background store traffic, not completion time.
    pub replica_pushes: u64,
    /// Bytes of background store traffic those pushes consumed.
    pub replica_bytes: u64,
}

struct SimManager {
    pool: WarmPool,
    /// Tasks routed here but not yet started (prefetch queue; §6.2).
    queue: VecDeque<usize>,
}

/// The simulated endpoint.
pub struct SimEndpoint {
    profile: SimProfile,
    scheduler: Box<dyn Scheduler>,
    batching: bool,
    managers: Vec<SimManager>,
    /// Views + per-type routing indexes, kept exact under every slot
    /// change (the agent's O(log M) dispatch structure).
    table: RoutingTable,
    /// ManagerId -> index (ids are UUID-normalised; not invertible).
    index_of: std::collections::HashMap<ManagerId, usize>,
    rng: Rng,
    /// When true, cold starts are deterministic (model mean) — makes
    /// sweep curves smooth; sampling remains available for realism.
    deterministic_cold: bool,
    /// Manager-side warm matching (from the scheduler; §6.2).
    warm_match: bool,
    /// Replica copies pushed per by-ref result (§5 survivability).
    /// Replication is asynchronous (service-side, fabric-to-fabric), so
    /// it contributes background store traffic — accounted in
    /// [`SimReport`] — without occupying the serial agent wire or the
    /// task's critical path.
    replication: usize,
}

/// The simulator's deterministic manager ids: index `i` ↔ bits `i + 1`.
fn sim_mid(i: usize) -> ManagerId {
    ManagerId::from_bits(i as u128 + 1)
}

impl SimEndpoint {
    pub fn new(
        profile: SimProfile,
        nodes: usize,
        scheduler: Box<dyn Scheduler>,
        batching: bool,
        seed: u64,
    ) -> Self {
        let managers: Vec<SimManager> = (0..nodes)
            .map(|_| SimManager {
                // Container idle timeout is irrelevant inside one batch
                // run (600 s default far exceeds any makespan segment).
                pool: WarmPool::new(profile.workers_per_node, 600.0),
                queue: VecDeque::new(),
            })
            .collect();
        let views: Vec<ManagerView> = managers
            .iter()
            .enumerate()
            .map(|(i, m)| ManagerView {
                id: sim_mid(i),
                deployed: m.pool.deployed_census(),
                warm_idle: m.pool.warm_census(),
                available_slots: m.pool.available_slots(),
                total_slots: m.pool.capacity(),
                queued: 0,
                endpoint: None,
                cold_start_est_s: m.pool.start_cost_estimate().unwrap_or(0.0),
            })
            .collect();
        let index_of = views
            .iter()
            .enumerate()
            .map(|(i, v): (usize, &ManagerView)| (v.id, i))
            .collect();
        let warm_match = scheduler.warm_matching();
        let table = RoutingTable::with_views(scheduler.prefetch(), views);
        SimEndpoint {
            profile,
            scheduler,
            batching,
            managers,
            table,
            index_of,
            rng: Rng::new(seed),
            deterministic_cold: false,
            warm_match,
            replication: 0,
        }
    }

    /// Use deterministic (mean) cold-start costs.
    pub fn deterministic_cold(mut self, on: bool) -> Self {
        self.deterministic_cold = on;
        self
    }

    /// Push `copies` replica copies of every by-ref result (§5
    /// survivability). Asynchronous in the live system, so the sim
    /// charges store traffic, not wire or completion time.
    pub fn with_replication(mut self, copies: usize) -> Self {
        self.replication = copies;
        self
    }

    /// Pre-warm all containers (§7.2's scaling methodology).
    pub fn prewarm(&mut self, types: &[ContainerId]) {
        for (i, m) in self.managers.iter_mut().enumerate() {
            m.pool.prewarm(types, 0.0);
            let id = sim_mid(i);
            let queued = self.table.view(id).map(|v| v.queued).unwrap_or(0);
            self.table.upsert(ManagerView {
                id,
                deployed: m.pool.deployed_census(),
                warm_idle: m.pool.warm_census(),
                available_slots: m.pool.available_slots(),
                total_slots: m.pool.capacity(),
                queued,
                endpoint: None,
                cold_start_est_s: m.pool.start_cost_estimate().unwrap_or(0.0),
            });
        }
    }

    /// Total container slots.
    pub fn total_workers(&self) -> usize {
        self.managers.len() * self.profile.workers_per_node
    }

    /// Run a concurrent batch of tasks to completion; returns the report.
    pub fn run(&mut self, tasks: &[SimTask]) -> SimReport {
        let mut q = EventQueue::new();
        let mut pending: VecDeque<usize> = (0..tasks.len()).collect();
        let mut completions: Vec<Time> = vec![0.0; tasks.len()];
        let mut completed = 0usize;
        let mut agent_idle = false;
        // Upstream result traffic shares the serial agent wire with
        // dispatch: completed results accumulate wire occupancy here and
        // the next dispatch drains it (by-ref outputs contribute a ref
        // frame; inline ones their full payload — §5 result offload).
        let mut result_wire_backlog: f64 = 0.0;
        // §5 survivability accounting: replica copies of by-ref results
        // (background store traffic, off the wire and the makespan).
        let mut replica_pushes: u64 = 0;
        let mut replica_bytes: u64 = 0;
        // Per-task dispatch cost: serial agent loop; unbatched dispatch
        // pays a request RTT per task (§7.5).
        let dispatch_cost = if self.batching {
            self.profile.dispatch_s
        } else {
            self.profile.dispatch_s + self.profile.rtt_s
        };
        let start_model = self.profile.start_model();

        q.schedule(0.0, Event::AgentDispatch);

        // Start as many queued tasks as manager `mi` can serve right now:
        // prefer queued tasks whose container is warm-idle (the manager
        // reuses deployed containers); otherwise FIFO head cold-starts,
        // evicting LRU warm containers of other types (§6.1–§6.2).
        macro_rules! try_start {
            ($self:ident, $mi:expr, $now:expr, $q:expr, $tasks:expr) => {{
                let mi = $mi;
                let mid = sim_mid(mi);
                loop {
                    let mgr = &$self.managers[mi];
                    if mgr.queue.is_empty() || mgr.pool.available_slots() == 0 {
                        break;
                    }
                    // Manager service policy (§6.2):
                    // * warming-aware coordination: start queued tasks in
                    //   warm matching containers; cold-start only types
                    //   with no container deployed here (empty slot or
                    //   LRU eviction); if every queued type is deployed
                    //   but busy, WAIT for a matching container to free
                    //   instead of killing a warm one.
                    // * baseline (non-warming-aware): serve FIFO — the
                    //   head task's container is started immediately,
                    //   killing a warm container on mismatch ("a
                    //   container worker is more likely to be killed to
                    //   serve other requests"; §7.4).
                    let pick = if $self.warm_match {
                        let warm = mgr.queue.iter().position(|&ti| {
                            let c = $tasks[ti]
                                .container
                                .unwrap_or(ContainerId(crate::Uuid::NIL));
                            mgr.pool.warm_idle_count(c) > 0
                        });
                        // Fair-share overflow (§6.2 "proportional to
                        // the number of received tasks"): spawn another
                        // container for a type whose queued demand
                        // exceeds its deployed count — covers both
                        // brand-new types (deployed == 0) and hot types
                        // that need more capacity than they have.
                        let overflow = || {
                            let mut queued_of: std::collections::HashMap<ContainerId, usize> =
                                std::collections::HashMap::new();
                            for &ti in mgr.queue.iter() {
                                let c = $tasks[ti]
                                    .container
                                    .unwrap_or(ContainerId(crate::Uuid::NIL));
                                *queued_of.entry(c).or_insert(0) += 1;
                            }
                            let qlen: usize = queued_of.values().sum();
                            let cap = mgr.pool.capacity();
                            mgr.queue.iter().position(|&ti| {
                                let c = $tasks[ti]
                                    .container
                                    .unwrap_or(ContainerId(crate::Uuid::NIL));
                                let q = queued_of.get(&c).copied().unwrap_or(0);
                                let dep = $self
                                    .table
                                    .view(mid)
                                    .and_then(|v| v.deployed.get(&c).copied())
                                    .unwrap_or(0);
                                // Spawn when the type holds less than its
                                // fair share of the pool (paper's
                                // proportional rule), with new types
                                // (dep == 0) always eligible.
                                let fair = cap * q / qlen.max(1);
                                // Deadband (dep + 1 < fair) prevents
                                // perpetual rebalance thrash on noisy
                                // queue compositions.
                                dep == 0 || dep + 1 < fair
                            })
                        };
                        let empty_slot =
                            mgr.pool.total() < mgr.pool.capacity();
                        match warm.or_else(overflow) {
                            Some(i) => i,
                            // Every queued type has enough containers
                            // deployed (busy): use an empty slot for the
                            // head if one exists, otherwise wait for a
                            // matching release instead of killing a warm
                            // container (§6.1).
                            None if empty_slot => 0,
                            None => break,
                        }
                    } else {
                        0
                    };
                    // Types with queued demand are protected from
                    // eviction (their tasks would be orphaned and cascade
                    // into more cold starts).
                    let protected: std::collections::HashSet<ContainerId> = $self.managers
                        [mi]
                        .queue
                        .iter()
                        .map(|&ti| {
                            $tasks[ti].container.unwrap_or(ContainerId(crate::Uuid::NIL))
                        })
                        .collect();
                    let mgr = &mut $self.managers[mi];
                    let task_idx = mgr.queue.remove(pick).unwrap();
                    let t = $tasks[task_idx];
                    let ctype =
                        t.container.unwrap_or(ContainerId(crate::Uuid::NIL));
                    let outcome = if $self.warm_match {
                        mgr.pool
                            .acquire_protected(ctype, $now, |c| c != ctype && protected.contains(&c))
                            .expect("available slot checked above")
                    } else {
                        mgr.pool
                            .acquire_detailed(ctype, $now)
                            .expect("available slot checked above")
                    };
                    let cold = outcome.cold;
                    let evicted = outcome.evicted;
                    $self.table.update(mid, |v| {
                        v.available_slots -= 1;
                        v.queued -= 1;
                        if cold {
                            *v.deployed.entry(ctype).or_insert(0) += 1;
                            if let Some(evicted) = evicted {
                                if let Some(n) = v.deployed.get_mut(&evicted) {
                                    *n = n.saturating_sub(1);
                                }
                                if let Some(n) = v.warm_idle.get_mut(&evicted) {
                                    *n = n.saturating_sub(1);
                                }
                            }
                        } else if let Some(n) = v.warm_idle.get_mut(&ctype) {
                            *n = n.saturating_sub(1);
                        }
                    });
                    let cold_cost = if outcome.cold {
                        if $self.deterministic_cold {
                            start_model.mean()
                        } else {
                            start_model.sample(&mut $self.rng)
                        }
                    } else {
                        0.0
                    };
                    // By-ref inputs are fetched once from the
                    // intra-endpoint store at the worker (§5.2).
                    // Strictly-greater matches the service's
                    // `input.len() > max_payload_bytes` offload rule.
                    let fetch_s = if t.input_bytes > $self.profile.ref_threshold_bytes {
                        t.input_bytes as f64 / $self.profile.store_bps
                    } else {
                        0.0
                    };
                    let done = $now
                        + cold_cost
                        + $self.profile.worker_overhead_s
                        + fetch_s
                        + t.duration_s;
                    $q.schedule(
                        done,
                        Event::WorkerDone { manager: mi, slot: outcome.slot, task: task_idx },
                    );
                }
            }};
        }

        while let Some((now, ev)) = q.next() {
            match ev {
                Event::AgentDispatch => {
                    let Some(&task_idx) = pending.front() else {
                        agent_idle = true;
                        continue;
                    };
                    let t = tasks[task_idx];
                    match self.scheduler.route_indexed(t.container, &self.table, &mut self.rng)
                    {
                        Some(mid) => {
                            pending.pop_front();
                            let mi = self.index_of[&mid];
                            self.table.update(mid, |v| v.queued += 1);
                            self.managers[mi].queue.push_back(task_idx);
                            try_start!(self, mi, now, q, tasks);
                            // Serial dispatcher: next task after d plus
                            // the wire time of whatever ships inline —
                            // by-ref tasks pay for a fixed DataRef frame
                            // instead of their payload (§5). The wire is
                            // modeled as serial link *occupancy* (it
                            // delays subsequent dispatches); per-task
                            // payload-arrival latency is folded into
                            // that serialization rather than tracked as
                            // a separate start delay per task.
                            let inline_bytes =
                                if t.input_bytes > self.profile.ref_threshold_bytes {
                                    REF_FRAME_BYTES
                                } else {
                                    t.input_bytes
                                };
                            let wire_s = inline_bytes as f64 / self.profile.wire_bps;
                            let upstream = std::mem::take(&mut result_wire_backlog);
                            q.schedule(
                                now + dispatch_cost + wire_s + upstream,
                                Event::AgentDispatch,
                            );
                            agent_idle = false;
                        }
                        None => {
                            // No capacity anywhere: stall until a worker
                            // frees up (WorkerDone re-arms us).
                            agent_idle = true;
                        }
                    }
                }
                Event::WorkerDone { manager, slot, task } => {
                    let pool = &mut self.managers[manager].pool;
                    let ctype = pool.slot_type(slot).expect("busy slot has a type");
                    pool.release(slot, now).expect("sim marked this slot busy");
                    self.table.update(sim_mid(manager), |v| {
                        v.available_slots += 1;
                        *v.warm_idle.entry(ctype).or_insert(0) += 1;
                    });
                    // The result crosses the serial wire upstream: a
                    // by-ref output ships its ref frame, an inline one
                    // its payload. The task completes once its result
                    // is off the endpoint.
                    let out_b = tasks[task].output_bytes;
                    let up_bytes = if out_b > self.profile.ref_threshold_bytes {
                        // By-ref result: the service pushes replica
                        // copies to peer stores asynchronously, off the
                        // critical path (the live-stack pin is the
                        // `chain_survives_ref_owner_death_via_replica`
                        // test; here only the traffic is accounted).
                        replica_pushes += self.replication as u64;
                        replica_bytes += self.replication as u64 * out_b;
                        REF_FRAME_BYTES
                    } else {
                        out_b
                    };
                    let result_wire_s = up_bytes as f64 / self.profile.wire_bps;
                    result_wire_backlog += result_wire_s;
                    completions[task] = now + result_wire_s;
                    completed += 1;
                    try_start!(self, manager, now, q, tasks);
                    if agent_idle && !pending.is_empty() {
                        q.schedule(now, Event::AgentDispatch);
                        agent_idle = false;
                    }
                }
                Event::StrategyTick | Event::NodeActive => {}
            }
        }

        assert_eq!(completed, tasks.len(), "task conservation violated");
        let completion_s = completions.iter().cloned().fold(0.0, f64::max);
        let (mut cold, mut warm, mut evict) = (0, 0, 0);
        for m in &self.managers {
            cold += m.pool.cold_starts();
            warm += m.pool.warm_hits();
            evict += m.pool.evictions();
        }
        SimReport {
            completion_s,
            tasks: tasks.len(),
            cold_starts: cold,
            warm_hits: warm,
            evictions: evict,
            mean_latency_s: completions.iter().sum::<f64>() / tasks.len().max(1) as f64,
            latency: crate::metrics::summarize(&completions),
            throughput: tasks.len() as f64 / completion_s.max(1e-9),
            replica_pushes,
            replica_bytes,
        }
    }

    /// Run a sequential task chain — stage k+1 dispatches only after
    /// stage k's result is back, its input being stage k's output (the
    /// A → B → C shape of §5 ref-forwarded pipelines). Warm container
    /// state persists across stages. Returns total chain completion
    /// time: with by-ref intermediates each hop ships two ref frames
    /// over the serial wire plus one store fetch at the worker; inline
    /// intermediates pay the full payload over the wire in both
    /// directions (`benches/datastore.rs` reports the ratio).
    pub fn run_chain(&mut self, stages: &[SimTask]) -> f64 {
        stages.iter().map(|t| self.run(std::slice::from_ref(t)).completion_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::{Randomized, WarmingAware};

    fn theta(nodes: usize, scheduler: Box<dyn Scheduler>) -> SimEndpoint {
        SimEndpoint::new(SimProfile::theta(), nodes, scheduler, true, 1)
            .deterministic_cold(true)
    }

    #[test]
    fn all_tasks_complete() {
        let mut ep = theta(2, Box::new(WarmingAware::default()));
        ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
        let r = ep.run(&vec![SimTask::noop(); 1000]);
        assert_eq!(r.tasks, 1000);
        assert!(r.completion_s > 0.0);
        assert_eq!(r.cold_starts, 0, "prewarmed run must have no cold starts");
    }

    #[test]
    fn strong_scaling_shape() {
        // Fig. 4(a): completion decreases with containers, flattening
        // near 256 for no-ops (agent dispatch bound).
        let m = 20_000;
        let run = |nodes: usize| {
            let mut ep = theta(nodes, Box::new(WarmingAware::default()));
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run(&vec![SimTask::noop(); m]).completion_s
        };
        let t1 = run(1); // 64 workers
        let t4 = run(4); // 256 workers
        let t16 = run(16); // 1024 workers
        assert!(t1 > t4 * 2.0, "scaling 64->256 should speed up ~4x: {t1} vs {t4}");
        let flat = t4 / t16;
        assert!(flat < 1.3, "beyond 256 containers no-ops are dispatch-bound: {t4} vs {t16}");
        // Agent-bound floor ≈ m * dispatch_s.
        let floor = m as f64 * SimProfile::theta().dispatch_s;
        assert!((t16 / floor) < 1.5, "floor {floor}, got {t16}");
    }

    #[test]
    fn peak_throughput_matches_calibration() {
        // §7.2.3: ~1694 tasks/s on Theta at scale.
        let mut ep = theta(8, Box::new(WarmingAware::default()));
        ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
        let r = ep.run(&vec![SimTask::noop(); 50_000]);
        assert!(
            (r.throughput - 1694.0).abs() / 1694.0 < 0.15,
            "throughput {}",
            r.throughput
        );
    }

    #[test]
    fn batching_ablation_matches_7_5() {
        // §7.5: 10 000 no-ops on 4 nodes (256 containers): 6.7 s batched
        // vs 118 s unbatched.
        let mk = |batching| {
            let mut ep = SimEndpoint::new(
                SimProfile::theta(),
                4,
                Box::new(WarmingAware::default()),
                batching,
                1,
            )
            .deterministic_cold(true);
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run(&vec![SimTask::noop(); 10_000]).completion_s
        };
        let on = mk(true);
        let off = mk(false);
        assert!((5.0..9.0).contains(&on), "batched {on}");
        assert!((100.0..140.0).contains(&off), "unbatched {off}");
        assert!(off / on > 10.0, "batching speedup {}", off / on);
    }

    #[test]
    fn warming_aware_beats_random_with_containers() {
        // Figs. 6–7 setup: 10 nodes x 10 workers, 10 container types,
        // uniform-random 3000-task batch, duration 0.
        let types: Vec<ContainerId> = (1..=10).map(|i| ContainerId::from_bits(i)).collect();
        let mut profile = SimProfile::theta();
        profile.workers_per_node = 10;
        let mut rng = Rng::new(7);
        let tasks: Vec<SimTask> = (0..3000)
            .map(|_| SimTask::with_container(*rng.choose(&types).unwrap(), 0.0))
            .collect();
        let run = |sched: Box<dyn Scheduler>| {
            SimEndpoint::new(profile, 10, sched, true, 11)
                .deterministic_cold(true)
                .run(&tasks)
        };
        // Prefetch (§6.2) lets managers queue ahead so warm containers
        // can pick matching tasks.
        let wa = run(Box::new(WarmingAware { prefetch: 10 }));
        let rnd = run(Box::new(Randomized { prefetch: 10 }));
        assert!(
            wa.cold_starts < rnd.cold_starts / 2,
            "warming-aware cold starts {} vs random {}",
            wa.cold_starts,
            rnd.cold_starts
        );
        assert!(
            wa.completion_s < rnd.completion_s,
            "warming-aware {} vs random {}",
            wa.completion_s,
            rnd.completion_s
        );
        // Paper: 22 cold starts for 3000 functions with warming-aware (on
        // an endpoint warmed by preceding batches). Our cold-started run
        // includes the 100-slot fill plus fair-share rebalance churn; the
        // invariant we hold is the *relative* claim: warming-aware colds
        // stay well under half of random's (see EXPERIMENTS.md E9/E10).
        assert!(wa.cold_starts <= 1400, "warming-aware cold starts {}", wa.cold_starts);
    }

    /// Bin-packing rides the capacity-ordered index through the sim's
    /// dispatch loop: tasks complete, the run is deterministic, and load
    /// concentrates (later nodes stay idle when early ones suffice) so
    /// the elastic strategy could release them.
    #[test]
    fn bin_packing_routes_through_capacity_index() {
        use crate::routing::BinPacking;
        let run = || {
            let mut ep = SimEndpoint::new(
                SimProfile::theta(),
                4,
                Box::new(BinPacking { prefetch: 4 }),
                true,
                21,
            )
            .deterministic_cold(true);
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run(&vec![SimTask::sleep(0.05); 500])
        };
        let a = run();
        let b = run();
        assert_eq!(a.tasks, 500);
        assert_eq!(a.completion_s, b.completion_s, "indexed bin-packing must be deterministic");
        assert_eq!(a.cold_starts, b.cold_starts);
        assert!(a.completion_s > 0.0);
    }

    /// §5 pass-by-reference: shipping big inputs as DataRef frames
    /// takes the payload bytes off the serial dispatch wire; the inline
    /// ordering is wire-bound, the by-ref one is dispatch-bound.
    #[test]
    fn ref_dispatch_beats_inline_for_large_payloads() {
        let tasks: Vec<SimTask> =
            (0..200).map(|_| SimTask::noop().with_input_bytes(20 * 1024 * 1024)).collect();
        let run = |profile: SimProfile| {
            let mut ep =
                SimEndpoint::new(profile, 4, Box::new(WarmingAware::default()), true, 5)
                    .deterministic_cold(true);
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run(&tasks).completion_s
        };
        // Default Theta profile: 20 MB > 10 MB threshold ⇒ by-ref.
        let by_ref = run(SimProfile::theta());
        // Threshold at infinity ⇒ everything ships inline.
        let mut inline_profile = SimProfile::theta();
        inline_profile.ref_threshold_bytes = u64::MAX;
        let inline = run(inline_profile);
        // 200 × 20 MB over the 1.25 GB/s wire is ≥ 3.2 s of serial wire
        // time alone; by-ref pays ~128 B per dispatch plus a parallel
        // 2 ms store fetch per worker.
        assert!(
            inline > by_ref * 3.0,
            "inline {inline} s should be ≥3x by-ref {by_ref} s"
        );
        assert!(by_ref < 1.0, "by-ref makespan stays dispatch-bound: {by_ref} s");
    }

    /// §5 result offload closes the loop: a 3-stage chain whose 64 MB
    /// intermediates stay in the endpoint store (ref frames on the
    /// wire, one store fetch per hop) completes far faster than the
    /// same chain shipping every intermediate inline both ways.
    #[test]
    fn ref_forwarded_chain_beats_inline_chain() {
        let mb64 = 64 * 1024 * 1024;
        let stages = [
            SimTask::noop().with_output_bytes(mb64),
            SimTask::noop().with_input_bytes(mb64).with_output_bytes(mb64),
            SimTask::noop().with_input_bytes(mb64),
        ];
        let run = |profile: SimProfile| {
            let mut ep =
                SimEndpoint::new(profile, 1, Box::new(WarmingAware::default()), true, 5)
                    .deterministic_cold(true);
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run_chain(&stages)
        };
        let by_ref = run(SimProfile::theta());
        let mut inline_profile = SimProfile::theta();
        inline_profile.ref_threshold_bytes = u64::MAX;
        let inline = run(inline_profile);
        // Inline pays two 64 MB result uploads over the 1.25 GB/s wire
        // (~107 ms); by-ref ships ref frames and pays two ~7 ms store
        // fetches instead — a ≥ 50 ms deterministic gap.
        assert!(
            inline > by_ref + 0.05,
            "inline chain {inline}s must trail ref-forwarded {by_ref}s"
        );
    }

    /// §5 survivability: replication of by-ref results is asynchronous,
    /// so it must not move the makespan at all — its cost is the
    /// accounted background store traffic (copies × output bytes).
    #[test]
    fn replication_stays_off_the_critical_path() {
        let mb64: u64 = 64 * 1024 * 1024;
        let tasks: Vec<SimTask> =
            (0..50).map(|_| SimTask::noop().with_output_bytes(mb64)).collect();
        let run = |copies: usize| {
            let mut ep =
                SimEndpoint::new(SimProfile::theta(), 2, Box::new(WarmingAware::default()), true, 5)
                    .deterministic_cold(true)
                    .with_replication(copies);
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run(&tasks)
        };
        let bare = run(0);
        let replicated = run(2);
        assert_eq!(bare.completion_s, replicated.completion_s, "replication is async");
        assert_eq!(bare.replica_pushes, 0);
        assert_eq!(bare.replica_bytes, 0);
        assert_eq!(replicated.replica_pushes, 100, "2 copies × 50 by-ref results");
        assert_eq!(replicated.replica_bytes, 100 * mb64);
        // Inline (small) outputs are never replicated: nothing to
        // survive — the bytes returned through the service.
        let small = {
            let mut ep = SimEndpoint::new(
                SimProfile::theta(),
                2,
                Box::new(WarmingAware::default()),
                true,
                5,
            )
            .deterministic_cold(true)
            .with_replication(2);
            ep.prewarm(&[ContainerId(crate::Uuid::NIL)]);
            ep.run(&vec![SimTask::noop().with_output_bytes(256); 50])
        };
        assert_eq!(small.replica_pushes, 0);
    }

    #[test]
    fn sim_is_deterministic() {
        let types: Vec<ContainerId> = (1..=4).map(ContainerId::from_bits).collect();
        let mut rng = Rng::new(3);
        let tasks: Vec<SimTask> = (0..500)
            .map(|_| SimTask::with_container(*rng.choose(&types).unwrap(), 0.1))
            .collect();
        let run = || {
            SimEndpoint::new(
                SimProfile::theta(),
                4,
                Box::new(WarmingAware::default()),
                true,
                99,
            )
            .run(&tasks)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.cold_starts, b.cold_starts);
    }
}
