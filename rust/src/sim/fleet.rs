//! Fleet-level simulation: a sharded service plane in front of many
//! simulated endpoints.
//!
//! The live stack shards its service plane behind a consistent-hash
//! ring ([`crate::service::ShardMap`]); this module drives the *same*
//! map under virtual time, so the simulator's shard assignment is
//! bit-identical to the live forwarder's. The cost model is the
//! pipeline bottleneck bound: each shard is a serial broker charging
//! `broker_cost_s` per task (the service-side hset/queue/notify work a
//! forwarder shard performs), each endpoint runs its tasks through the
//! full [`SimEndpoint`] model, and the fleet makespan is the slower of
//! the two layers. Sharding N ways divides the broker layer's serial
//! cost by the ring's balance — the simulated counterpart of the
//! tasks/s-per-shard curve pinned in `benches/hotpath.rs`.

use crate::common::ids::TaskId;
use crate::service::ShardMap;
use crate::sim::endpoint::{SimEndpoint, SimTask};

/// Results of one fleet run.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Fleet makespan: the slower of the broker layer and the slowest
    /// endpoint, seconds.
    pub completion_s: f64,
    pub tasks: usize,
    /// Achieved fleet-wide throughput, tasks/s.
    pub throughput: f64,
    /// Tasks brokered by each service shard (ring balance).
    pub shard_tasks: Vec<usize>,
    /// Serial brokering time of the most loaded shard, seconds.
    pub broker_bound_s: f64,
    /// Completion time of the slowest endpoint, seconds.
    pub endpoint_bound_s: f64,
}

/// A sharded service plane over a set of simulated endpoints.
pub struct SimFleet {
    map: ShardMap,
    endpoints: Vec<SimEndpoint>,
    /// Serial per-task brokering cost at one forwarder shard, seconds.
    broker_cost_s: f64,
}

impl SimFleet {
    pub fn new(shards: usize, endpoints: Vec<SimEndpoint>, broker_cost_s: f64) -> Self {
        SimFleet { map: ShardMap::new(shards), endpoints, broker_cost_s }
    }

    pub fn shard_map(&self) -> ShardMap {
        self.map
    }

    /// Run `per_endpoint` copies of `task` on every endpoint, tasks
    /// hashed onto the shard ring exactly as the live service plane
    /// hashes them.
    pub fn run(&mut self, task: SimTask, per_endpoint: usize) -> FleetReport {
        let total = per_endpoint * self.endpoints.len();
        let mut shard_tasks = vec![0usize; self.map.shards()];
        for _ in 0..total {
            shard_tasks[self.map.shard_for_task(TaskId::new())] += 1;
        }
        let broker_bound_s =
            shard_tasks.iter().copied().max().unwrap_or(0) as f64 * self.broker_cost_s;
        let batch: Vec<SimTask> = vec![task; per_endpoint];
        let endpoint_bound_s = self
            .endpoints
            .iter_mut()
            .map(|e| e.run(&batch).completion_s)
            .fold(0.0f64, f64::max);
        let completion_s = broker_bound_s.max(endpoint_bound_s);
        FleetReport {
            completion_s,
            tasks: total,
            throughput: if completion_s > 0.0 { total as f64 / completion_s } else { 0.0 },
            shard_tasks,
            broker_bound_s,
            endpoint_bound_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::Randomized;
    use crate::sim::profile::SimProfile;

    fn fleet(shards: usize, endpoints: usize) -> SimFleet {
        let eps = (0..endpoints)
            .map(|i| {
                SimEndpoint::new(
                    SimProfile::theta(),
                    8,
                    Box::new(Randomized { prefetch: 10 }),
                    true,
                    7 + i as u64,
                )
            })
            .collect();
        // 1 ms serial brokering per task: broker-bound for no-op
        // batches, so the shard count is what the makespan measures.
        SimFleet::new(shards, eps, 1e-3)
    }

    #[test]
    fn ring_balance_matches_the_live_map() {
        let mut f = fleet(4, 4);
        let r = f.run(SimTask::noop(), 2000);
        assert_eq!(r.tasks, 8000);
        assert_eq!(r.shard_tasks.len(), 4);
        let ideal = r.tasks / 4;
        for (i, n) in r.shard_tasks.iter().enumerate() {
            assert!(
                *n <= 2 * ideal && *n > 0,
                "shard {i} brokered {n} of {} tasks — ring badly unbalanced",
                r.tasks
            );
        }
    }

    #[test]
    fn broker_bound_fleet_scales_with_shard_count() {
        let t1 = fleet(1, 4).run(SimTask::noop(), 2000).throughput;
        let t4 = fleet(4, 4).run(SimTask::noop(), 2000).throughput;
        assert!(
            t4 >= 2.5 * t1,
            "simulated shard scaling: N=4 gives {t4:.0} tasks/s vs {t1:.0} at N=1"
        );
    }

    #[test]
    fn endpoint_bound_fleet_ignores_extra_shards() {
        // Long tasks: the endpoint layer dominates and more shards
        // cannot help — the report must say which bound is active.
        let mut f = fleet(8, 2);
        let r = f.run(SimTask::sleep(1.0), 64);
        assert!(r.endpoint_bound_s > r.broker_bound_s);
        assert!((r.completion_s - r.endpoint_bound_s).abs() < 1e-9);
    }
}
