//! The event queue: a time-ordered heap with deterministic FIFO
//! tie-breaking (sequence numbers), so equal-time events fire in
//! insertion order and runs are reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::common::time::Time;

/// Simulator events.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// The agent processes the next pending task (serial dispatcher).
    AgentDispatch,
    /// A worker finished a task on (manager, slot).
    WorkerDone { manager: usize, slot: usize, task: usize },
    /// Elastic-strategy monitoring tick (§6.3).
    StrategyTick,
    /// A provisioned node became active.
    NodeActive,
}

struct Entry {
    at: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earlier time first; FIFO on ties.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-time event queue.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    now: Time,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `event` at absolute time `at` (>= now).
    pub fn schedule(&mut self, at: Time, event: Event) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.heap.push(Entry { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay.
    pub fn after(&mut self, delay: Time, event: Event) {
        self.schedule(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock. `None` when drained.
    pub fn next(&mut self) -> Option<(Time, Event)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now, "event time ran backwards");
        self.now = e.at;
        Some((e.at, e.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ordering() {
        let mut q = EventQueue::new();
        q.schedule(3.0, Event::StrategyTick);
        q.schedule(1.0, Event::AgentDispatch);
        q.schedule(2.0, Event::NodeActive);
        assert_eq!(q.next().unwrap().0, 1.0);
        assert_eq!(q.next().unwrap().0, 2.0);
        assert_eq!(q.next().unwrap().0, 3.0);
        assert!(q.next().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for task in 0..10 {
            q.schedule(1.0, Event::WorkerDone { manager: 0, slot: 0, task });
        }
        for task in 0..10 {
            match q.next().unwrap().1 {
                Event::WorkerDone { task: t, .. } => assert_eq!(t, task),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, Event::AgentDispatch);
        q.after(1.0, Event::StrategyTick); // at t=1
        let (t1, _) = q.next().unwrap();
        let (t2, _) = q.next().unwrap();
        assert!(t2 >= t1);
        assert_eq!(q.now(), 5.0);
    }
}

#[cfg(test)]
mod proptests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn events_always_pop_in_time_order() {
        check("event-order", 200, |g| {
            let mut q = EventQueue::new();
            let n = g.usize(1, 200);
            for _ in 0..n {
                q.schedule(g.f64(0.0, 1000.0), Event::AgentDispatch);
            }
            let mut last = f64::NEG_INFINITY;
            while let Some((t, _)) = q.next() {
                assert!(t >= last);
                last = t;
            }
        });
    }
}
