//! Fault injection over the ref lifecycle (§5 data fabric): every
//! injected fault — eviction mid-flight, owner disconnect, checksum
//! corruption, TTL expiry, clock skew, crash mid-spill — must surface a
//! *typed* error (`Error::NotFound` / `Error::Corrupt`) and fail the
//! affected task cleanly at the worker within a bounded wait. Never a
//! hang, never a panic, never wrong bytes.
//!
//! The scenarios are deterministic: faults are injected at fixed points
//! between `put` and `resolve`, and virtual clocks drive every
//! time-dependent case.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use funcx::common::ids::{EndpointId, FunctionId, UserId};
use funcx::common::sync::Notify;
use funcx::common::task::{Payload, Task, TaskResult, TaskState};
use funcx::common::time::{Clock, VirtualClock, WallClock};
use funcx::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
use funcx::datastore::{DataFabric, DataRef, TieredConfig, TieredStore};
use funcx::endpoint::{Manager, ManagerCtx};
use funcx::metrics::LatencyBreakdown;
use funcx::runtime::PayloadExecutor;
use funcx::serialize::{pack, unpack, Buffer, Value};
use funcx::Error;

/// Drive one by-ref Echo task through a real manager + worker against
/// `fabric`, and return its result within a bounded wait. The harness
/// itself asserts the no-hang half of every scenario.
fn run_ref_task(fabric: Arc<DataFabric>, clock: Arc<dyn Clock>, dref: DataRef) -> TaskResult {
    let (tx, rx) = channel();
    let ctx = ManagerCtx {
        executor: Arc::new(PayloadExecutor::bare()),
        results: tx,
        wake: Arc::new(Notify::new()),
        result_batch: 1,
        endpoint: Some(fabric.local().owner()),
        fabric: Some(fabric),
        max_result_bytes: usize::MAX,
        clock,
        latency: Arc::new(LatencyBreakdown::new()),
        start_model: TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None),
        cold_start_scale: 0.001,
    };
    let m = Manager::spawn(1, 600.0, ctx, 1);
    let task = Task::new(
        FunctionId::new(),
        EndpointId::new(),
        UserId::new(),
        None,
        Payload::Echo,
        Buffer::empty(),
    )
    .with_input_ref(dref);
    m.enqueue(vec![Arc::new(task)]);
    let batch = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("faulted task must produce a result, not hang");
    m.shutdown();
    batch.into_iter().next().expect("one result")
}

/// The failure message a faulted task carries back to the caller.
fn failure_message(r: &TaskResult) -> String {
    assert_eq!(r.state, TaskState::Failed, "fault must fail the task, not {:?}", r.state);
    unpack(&r.output)
        .ok()
        .and_then(|v| v.as_str().map(str::to_string))
        .unwrap_or_default()
}

fn store() -> Arc<TieredStore> {
    Arc::new(TieredStore::new(EndpointId::new(), TieredConfig::default()).unwrap())
}

fn frame(byte: u8, len: usize) -> Buffer {
    pack(&Value::Bytes(vec![byte; len]), 0).unwrap()
}

/// Fault: the ref's frame is evicted between dispatch and the worker's
/// resolve (the store owner reclaimed it). The task fails `not found`.
#[test]
fn ref_evicted_mid_flight_fails_typed() {
    let s = store();
    let fabric = Arc::new(DataFabric::new(s.clone()));
    let dref = fabric.put("task-input:victim", frame(0x11, 8 << 10), 0.0).unwrap();
    // Mid-flight eviction, after the ref was minted and "dispatched".
    assert!(s.remove("task-input:victim").unwrap());
    assert!(matches!(fabric.resolve(&dref, 0.0), Err(Error::NotFound(_))));
    let r = run_ref_task(fabric, Arc::new(WallClock::new()), dref);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
}

/// Fault: the owning endpoint disconnects before the fetch. Peer-held
/// refs stop resolving with `NotFound`; frames already verified into
/// the resolve cache keep serving.
#[test]
fn owner_disconnected_before_fetch_fails_typed() {
    let owner = store();
    let mine = store();
    let fabric = Arc::new(DataFabric::new(mine));
    fabric.connect_peer(owner.owner(), owner.clone());
    let cached = owner.put("task-input:cached", frame(0x22, 4 << 10), 0.0).unwrap();
    let uncached = owner.put("task-input:uncached", frame(0x33, 4 << 10), 0.0).unwrap();
    // Warm the cache with one of the two, then lose the peer.
    fabric.resolve(&cached, 0.0).unwrap();
    assert!(fabric.disconnect_peer(owner.owner()));
    assert!(!fabric.disconnect_peer(owner.owner()), "second disconnect is a no-op");

    match fabric.resolve(&uncached, 0.0) {
        Err(Error::NotFound(m)) => assert!(m.contains("unreachable"), "{m}"),
        other => panic!("expected NotFound, got {other:?}"),
    }
    assert!(fabric.resolve(&cached, 0.0).is_ok(), "verified cache entries survive peer loss");

    let r = run_ref_task(fabric, Arc::new(WallClock::new()), uncached);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
}

/// Fault: the frame fetched from a peer no longer matches the ref's
/// checksum (the owner overwrote the key; same length, different
/// bytes). The forward surfaces `Error::Corrupt` — wrong data is never
/// silently delivered — and the task fails with the corrupt message.
#[test]
fn checksum_mismatch_on_peer_forward_is_corrupt() {
    let owner = store();
    let mine = store();
    let fabric = Arc::new(DataFabric::new(mine));
    fabric.connect_peer(owner.owner(), owner.clone());
    let stale = owner.put("task-input:k", frame(0x44, 4 << 10), 0.0).unwrap();
    // Same key, same length, different content: size check passes, the
    // checksum catches it.
    owner.put("task-input:k", frame(0x55, 4 << 10), 0.0).unwrap();
    match fabric.resolve(&stale, 0.0) {
        Err(Error::Corrupt(m)) => assert!(m.contains("checksum"), "{m}"),
        other => panic!("expected Corrupt, got {other:?}"),
    }
    let r = run_ref_task(fabric, Arc::new(WallClock::new()), stale);
    assert!(failure_message(&r).contains("corrupt"), "got: {}", failure_message(&r));
}

/// Fault: the ref's TTL lapses between `put` and the worker's resolve
/// (driven on a virtual clock). `NotFound`, and the frame is gone for
/// good — a later resolve at an even later time stays `NotFound`.
#[test]
fn ttl_expiry_between_put_and_resolve_fails_typed() {
    let vc = VirtualClock::new();
    let s = Arc::new(
        TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 5.0, spool_dir: None },
        )
        .unwrap(),
    );
    let fabric = Arc::new(DataFabric::new(s));
    let dref = fabric.put("task-input:short", frame(0x66, 2 << 10), vc.now()).unwrap();
    assert!(fabric.resolve(&dref, vc.now()).is_ok(), "live before expiry");
    vc.advance_to(6.0);
    assert!(matches!(fabric.resolve(&dref, vc.now()), Err(Error::NotFound(_))));
    let r = run_ref_task(fabric, Arc::new(vc), dref);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
}

/// Fix pin (ROADMAP "store-owned clocks"): with owner-stamped expiry, a
/// resolving peer whose clock disagrees by ± the full TTL neither
/// expires a live entry early nor resurrects a dead one.
#[test]
fn skewed_peer_clocks_cannot_mis_expire() {
    let owner_clock = VirtualClock::new();
    let ttl = 10.0;
    let owner_store = Arc::new(
        TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: ttl, spool_dir: None },
        )
        .unwrap()
        .with_owner_clock(Arc::new(owner_clock.clone())),
    );
    let reader = Arc::new(DataFabric::new(store()));
    reader.connect_peer(owner_store.owner(), owner_store.clone());
    let dref = owner_store.put("task-input:skew", frame(0x77, 2 << 10), 0.0).unwrap();

    // Reader clock running a full TTL *ahead*: the entry is still live
    // on the owner's clock, so the resolve must succeed.
    let got = reader.resolve(&dref, ttl + 1.0).unwrap();
    assert_eq!(got.len(), frame(0x77, 2 << 10).len());

    // Owner's clock passes the stamp: now the entry is dead, and a
    // reader running a full TTL *behind* must not resurrect it.
    owner_store.evict_expired(0.0); // skewed caller `now` is ignored too
    owner_clock.advance_to(ttl + 1.0);
    assert!(matches!(owner_store.resolve(&dref, -ttl), Err(Error::NotFound(_))));
    // (The reader's earlier fetch lives in its verified cache; a fresh
    // fabric sees the expiry.)
    let fresh = DataFabric::new(store());
    fresh.connect_peer(owner_store.owner(), owner_store.clone());
    assert!(matches!(fresh.resolve(&dref, -ttl), Err(Error::NotFound(_))));
}

/// Fix pin (ROADMAP "spool GC / crash recovery"): a store killed
/// mid-spill leaks nothing — on recovery, fully-spilled frames readopt
/// byte-identical under the old epoch (in-flight refs keep resolving),
/// the interrupted spill is reclaimed, and memory-tier refs that died
/// with the process fail `NotFound`, not wrong data.
#[test]
fn crash_mid_spill_recovers_without_leaks() {
    let dir = std::env::temp_dir().join(format!("funcx-faults-spool-{}", funcx::Uuid::new()));
    let owner = EndpointId::new();
    let cfg = TieredConfig {
        mem_high_watermark: 16 * 1024, // one 12 KB frame resident at most
        default_ttl_s: 0.0,
        spool_dir: Some(dir.clone()),
    };
    let spilled_bytes = frame(0x88, 12 << 10);
    let (spilled_ref, resident_ref) = {
        let s = TieredStore::new(owner, cfg.clone()).unwrap();
        let spilled = s.put("chain:spilled", spilled_bytes.clone(), 0.0).unwrap();
        // The second put pushes the first to disk (background spiller)
        // and stays in memory.
        let resident = s.put("chain:resident", frame(0x99, 12 << 10), 0.0).unwrap();
        assert!(s.settle(Duration::from_secs(10)), "spill must complete before the crash");
        assert_eq!(s.tier_of("chain:spilled"), Some(funcx::datastore::Tier::Disk));
        assert_eq!(s.tier_of("chain:resident"), Some(funcx::datastore::Tier::Memory));
        std::mem::forget(s); // crash: no Drop, no cleanup
        (spilled, resident)
    };
    // Interrupted spill: a frame file the manifest never recorded.
    std::fs::write(dir.join("torn.0123456789abcdef"), [0u8; 64]).unwrap();

    let recovered = Arc::new(TieredStore::recover(owner, cfg).unwrap());
    // No leaked files after recovery: exactly the one readopted frame
    // remains (plus the manifest) — the torn orphan was reclaimed.
    // (Checked before any resolve: a resolve may promote the frame back
    // to memory and legitimately retire the spool file.)
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    assert_eq!(names.len(), 2, "spool must hold one frame + manifest, got {names:?}");
    assert!(names.iter().any(|n| n.starts_with("chain_spilled")), "{names:?}");
    assert!(names.contains(&"spool.manifest".to_string()), "{names:?}");
    // Byte-identical readopt under the old epoch: the in-flight ref
    // resolves as if the crash never happened.
    let got = recovered.resolve(&spilled_ref, 0.0).unwrap();
    assert_eq!(got.as_slice(), spilled_bytes.as_slice());
    // The memory-tier frame died with the process: typed NotFound.
    assert!(matches!(recovered.resolve(&resident_ref, 0.0), Err(Error::NotFound(_))));

    // And the whole fault still fails a *task* cleanly, not just a
    // direct resolve.
    let fabric = Arc::new(DataFabric::new(recovered));
    let r = run_ref_task(fabric, Arc::new(WallClock::new()), resident_ref);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));

    std::fs::remove_dir_all(&dir).unwrap();
}

/// Fault: crash mid-manifest-compaction. The manifest is an append-only
/// log compacted via write-to-temp + rename; a crash that leaves a
/// half-written `.tmp` (and a torn final append in the live log) must
/// not cost a single committed frame: recovery replays the intact log,
/// readopts every spilled frame byte-identical, and ignores the temp.
#[test]
fn crash_mid_manifest_compaction_recovers_all_frames() {
    let dir = std::env::temp_dir().join(format!("funcx-faults-compact-{}", funcx::Uuid::new()));
    let owner = EndpointId::new();
    let cfg = TieredConfig {
        mem_high_watermark: 0, // everything spills; every spill appends
        default_ttl_s: 0.0,
        spool_dir: Some(dir.clone()),
    };
    let refs: Vec<(DataRef, Buffer)> = {
        let s = TieredStore::new(owner, cfg.clone()).unwrap();
        let refs: Vec<(DataRef, Buffer)> = (0..8)
            .map(|i| {
                let f = frame(0x10 + i as u8, 4 << 10);
                (s.put(&format!("chain:k{i}"), f.clone(), 0.0).unwrap(), f)
            })
            .collect();
        assert!(s.settle(Duration::from_secs(10)), "all spills must commit");
        std::mem::forget(s); // crash: no Drop, no cleanup
        refs
    };
    // The crash struck mid-compaction: a partial snapshot that never
    // renamed over the live log…
    std::fs::write(dir.join("spool.manifest.tmp"), "v2 1\n+ dead-partial").unwrap();
    // …and mid-append: a torn final record on the live log itself.
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("spool.manifest"))
            .unwrap();
        f.write_all(b"+ 746f726e 12").unwrap(); // no checksum/expiry/newline
    }

    let recovered = Arc::new(TieredStore::recover(owner, cfg).unwrap());
    assert_eq!(recovered.len(), 8, "every committed spill survives the torn log");
    for (r, bytes) in &refs {
        let got = recovered.resolve(r, 0.0).unwrap();
        assert_eq!(got.as_slice(), bytes.as_slice(), "byte-identical after compaction crash");
    }
    // And the whole fault still fails nothing at the task level: a
    // by-ref task over a recovered frame succeeds.
    let fabric = Arc::new(DataFabric::new(recovered));
    let ok = run_ref_task(fabric, Arc::new(WallClock::new()), refs[0].0.clone());
    assert_eq!(ok.state, TaskState::Success);

    std::fs::remove_dir_all(&dir).unwrap();
}

/// The harness's own sanity: an unfaulted by-ref task succeeds, so the
/// failures above are the faults' doing, not the harness's.
#[test]
fn unfaulted_ref_task_succeeds() {
    let fabric = Arc::new(DataFabric::new(store()));
    let input = Value::Bytes(vec![0xAA; 4 << 10]);
    let dref = fabric.put("task-input:ok", pack(&input, 0).unwrap(), 0.0).unwrap();
    let r = run_ref_task(fabric, Arc::new(WallClock::new()), dref);
    assert_eq!(r.state, TaskState::Success);
    assert_eq!(unpack(&r.output).unwrap(), input);
}
