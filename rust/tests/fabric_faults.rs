//! Fault injection over the ref lifecycle (§5 data fabric): every
//! injected fault — eviction mid-flight, owner disconnect, checksum
//! corruption, TTL expiry, clock skew, crash mid-spill — must surface a
//! *typed* error (`Error::NotFound` / `Error::Corrupt`) and fail the
//! affected task cleanly at the worker within a bounded wait. Never a
//! hang, never a panic, never wrong bytes.
//!
//! The scenarios are deterministic: faults are injected at fixed points
//! between `put` and `resolve`, and virtual clocks drive every
//! time-dependent case.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::ids::{EndpointId, FunctionId, UserId};
use funcx::common::sync::Notify;
use funcx::common::task::{Payload, Task, TaskResult, TaskState};
use funcx::common::time::{Clock, VirtualClock, WallClock};
use funcx::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
use funcx::datastore::{
    DataFabric, DataRef, DiskBackend, SpoolStore, StoreBackend, TieredConfig, TieredStore,
};
use funcx::endpoint::{link, EndpointBuilder, Manager, ManagerCtx};
use funcx::metrics::{Counters, FlightRecorder, LatencyBreakdown, TaskTrace, TraceKind};
use funcx::registry::EndpointStatus;
use funcx::runtime::PayloadExecutor;
use funcx::serialize::{pack, unpack, Buffer, Value};
use funcx::service::FuncXService;
use funcx::Error;

/// Drive one by-ref Echo task through a real manager + worker against
/// `fabric`, and return its result within a bounded wait. The harness
/// itself asserts the no-hang half of every scenario.
fn run_ref_task(fabric: Arc<DataFabric>, clock: Arc<dyn Clock>, dref: DataRef) -> TaskResult {
    run_ref_task_traced(fabric, clock, dref).0
}

/// Same harness with a live flight recorder wired through worker and
/// fabric: every scenario also gets its task's assembled trace, so the
/// fault tests can pin that the *timeline* ends in the matching typed
/// error — not just that some failure string came back.
fn run_ref_task_traced(
    fabric: Arc<DataFabric>,
    clock: Arc<dyn Clock>,
    dref: DataRef,
) -> (TaskResult, TaskTrace) {
    let recorder = Arc::new(FlightRecorder::default());
    fabric.with_recorder(recorder.clone());
    let (tx, rx) = channel();
    let ctx = ManagerCtx {
        executor: Arc::new(PayloadExecutor::bare()),
        results: tx,
        wake: Arc::new(Notify::new()),
        result_batch: 1,
        endpoint: Some(fabric.local().owner()),
        fabric: Some(fabric),
        max_result_bytes: usize::MAX,
        clock,
        latency: Arc::new(LatencyBreakdown::new()),
        recorder: recorder.clone(),
        start_model: TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None),
        cold_start_scale: 0.001,
        pipeline_depth: 1,
    };
    let m = Manager::spawn(1, 600.0, ctx, 1);
    let mut task = Task::new(
        FunctionId::new(),
        EndpointId::new(),
        UserId::new(),
        None,
        Payload::Echo,
        Buffer::empty(),
    )
    .with_input_ref(dref);
    task.trace = Some(recorder.mint(task.id));
    let id = task.id;
    m.enqueue(vec![Arc::new(task)]);
    let batch = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("faulted task must produce a result, not hang");
    m.shutdown();
    let r = batch.into_iter().next().expect("one result");
    let trace = recorder.assemble(id).expect("a traced task must assemble a timeline");
    (r, trace)
}

/// Assert the trace's terminal event is a worker-side `TaskFailed`
/// carrying exactly the injected typed error kind, and that the fabric
/// also logged a `ResolveFailed` with the same kind on the way down.
fn assert_fault_trace(trace: &TaskTrace, kind: &str) {
    match &trace.terminal().expect("faulted task's trace must close").kind {
        TraceKind::TaskFailed { error } => {
            assert_eq!(*error, kind, "terminal error kind\n{}", trace.render())
        }
        other => panic!("terminal must be TaskFailed, got {other:?}\n{}", trace.render()),
    }
    assert!(
        trace
            .events
            .iter()
            .any(|e| matches!(&e.kind, TraceKind::ResolveFailed { error, .. } if *error == kind)),
        "fabric must log ResolveFailed({kind})\n{}",
        trace.render()
    );
}

/// The failure message a faulted task carries back to the caller.
fn failure_message(r: &TaskResult) -> String {
    assert_eq!(r.state, TaskState::Failed, "fault must fail the task, not {:?}", r.state);
    unpack(&r.output)
        .ok()
        .and_then(|v| v.as_str().map(str::to_string))
        .unwrap_or_default()
}

fn store() -> Arc<TieredStore> {
    Arc::new(TieredStore::new(EndpointId::new(), TieredConfig::default()).unwrap())
}

/// Seed for CI's churn kill-matrix: perturbs storm widths and payload
/// sizes so each matrix leg drives the same fault sequence through
/// different shapes. Defaults to 0 under plain `cargo test`.
fn chaos_seed() -> usize {
    std::env::var("FUNCX_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn frame(byte: u8, len: usize) -> Buffer {
    pack(&Value::Bytes(vec![byte; len]), 0).unwrap()
}

/// Fault: the ref's frame is evicted between dispatch and the worker's
/// resolve (the store owner reclaimed it). The task fails `not found`.
#[test]
fn ref_evicted_mid_flight_fails_typed() {
    let s = store();
    let fabric = Arc::new(DataFabric::new(s.clone()));
    let dref = fabric.put("task-input:victim", frame(0x11, 8 << 10), 0.0).unwrap();
    // Mid-flight eviction, after the ref was minted and "dispatched".
    assert!(s.remove("task-input:victim").unwrap());
    assert!(matches!(fabric.resolve(&dref, 0.0), Err(Error::NotFound(_))));
    let (r, trace) = run_ref_task_traced(fabric, Arc::new(WallClock::new()), dref);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
    assert_fault_trace(&trace, "NotFound");
}

/// Fault: the owning endpoint disconnects before the fetch. Peer-held
/// refs stop resolving with `NotFound`; frames already verified into
/// the resolve cache keep serving.
#[test]
fn owner_disconnected_before_fetch_fails_typed() {
    let owner = store();
    let mine = store();
    let fabric = Arc::new(DataFabric::new(mine));
    fabric.connect_peer(owner.owner(), owner.clone());
    let cached = owner.put("task-input:cached", frame(0x22, 4 << 10), 0.0).unwrap();
    let uncached = owner.put("task-input:uncached", frame(0x33, 4 << 10), 0.0).unwrap();
    // Warm the cache with one of the two, then lose the peer.
    fabric.resolve(&cached, 0.0).unwrap();
    assert!(fabric.disconnect_peer(owner.owner()));
    assert!(!fabric.disconnect_peer(owner.owner()), "second disconnect is a no-op");

    match fabric.resolve(&uncached, 0.0) {
        Err(Error::NotFound(m)) => assert!(m.contains("unreachable"), "{m}"),
        other => panic!("expected NotFound, got {other:?}"),
    }
    assert!(fabric.resolve(&cached, 0.0).is_ok(), "verified cache entries survive peer loss");

    let (r, trace) = run_ref_task_traced(fabric, Arc::new(WallClock::new()), uncached);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
    assert_fault_trace(&trace, "NotFound");
}

/// Fault: the frame fetched from a peer no longer matches the ref's
/// checksum (the owner overwrote the key; same length, different
/// bytes). The forward surfaces `Error::Corrupt` — wrong data is never
/// silently delivered — and the task fails with the corrupt message.
#[test]
fn checksum_mismatch_on_peer_forward_is_corrupt() {
    let owner = store();
    let mine = store();
    let fabric = Arc::new(DataFabric::new(mine));
    fabric.connect_peer(owner.owner(), owner.clone());
    let stale = owner.put("task-input:k", frame(0x44, 4 << 10), 0.0).unwrap();
    // Same key, same length, different content: size check passes, the
    // checksum catches it.
    owner.put("task-input:k", frame(0x55, 4 << 10), 0.0).unwrap();
    match fabric.resolve(&stale, 0.0) {
        Err(Error::Corrupt(m)) => assert!(m.contains("checksum"), "{m}"),
        other => panic!("expected Corrupt, got {other:?}"),
    }
    let (r, trace) = run_ref_task_traced(fabric, Arc::new(WallClock::new()), stale);
    assert!(failure_message(&r).contains("corrupt"), "got: {}", failure_message(&r));
    assert_fault_trace(&trace, "Corrupt");
}

/// Fault: the ref's TTL lapses between `put` and the worker's resolve
/// (driven on a virtual clock). `NotFound`, and the frame is gone for
/// good — a later resolve at an even later time stays `NotFound`.
#[test]
fn ttl_expiry_between_put_and_resolve_fails_typed() {
    let vc = VirtualClock::new();
    let s = Arc::new(
        TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 5.0, spool_dir: None },
        )
        .unwrap(),
    );
    let fabric = Arc::new(DataFabric::new(s));
    let dref = fabric.put("task-input:short", frame(0x66, 2 << 10), vc.now()).unwrap();
    assert!(fabric.resolve(&dref, vc.now()).is_ok(), "live before expiry");
    vc.advance_to(6.0);
    assert!(matches!(fabric.resolve(&dref, vc.now()), Err(Error::NotFound(_))));
    let (r, trace) = run_ref_task_traced(fabric, Arc::new(vc), dref);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
    assert_fault_trace(&trace, "NotFound");
}

/// Fix pin (ROADMAP "store-owned clocks"): with owner-stamped expiry, a
/// resolving peer whose clock disagrees by ± the full TTL neither
/// expires a live entry early nor resurrects a dead one.
#[test]
fn skewed_peer_clocks_cannot_mis_expire() {
    let owner_clock = VirtualClock::new();
    let ttl = 10.0;
    let owner_store = Arc::new(
        TieredStore::new(
            EndpointId::new(),
            TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: ttl, spool_dir: None },
        )
        .unwrap()
        .with_owner_clock(Arc::new(owner_clock.clone())),
    );
    let reader = Arc::new(DataFabric::new(store()));
    reader.connect_peer(owner_store.owner(), owner_store.clone());
    let dref = owner_store.put("task-input:skew", frame(0x77, 2 << 10), 0.0).unwrap();

    // Reader clock running a full TTL *ahead*: the entry is still live
    // on the owner's clock, so the resolve must succeed.
    let got = reader.resolve(&dref, ttl + 1.0).unwrap();
    assert_eq!(got.len(), frame(0x77, 2 << 10).len());

    // Owner's clock passes the stamp: now the entry is dead, and a
    // reader running a full TTL *behind* must not resurrect it.
    owner_store.evict_expired(0.0); // skewed caller `now` is ignored too
    owner_clock.advance_to(ttl + 1.0);
    assert!(matches!(owner_store.resolve(&dref, -ttl), Err(Error::NotFound(_))));
    // (The reader's earlier fetch lives in its verified cache; a fresh
    // fabric sees the expiry.)
    let fresh = DataFabric::new(store());
    fresh.connect_peer(owner_store.owner(), owner_store.clone());
    assert!(matches!(fresh.resolve(&dref, -ttl), Err(Error::NotFound(_))));
}

/// Fix pin (ROADMAP "spool GC / crash recovery"): a store killed
/// mid-spill leaks nothing — on recovery, fully-spilled frames readopt
/// byte-identical under the old epoch (in-flight refs keep resolving),
/// the interrupted spill is reclaimed, and memory-tier refs that died
/// with the process fail `NotFound`, not wrong data.
#[test]
fn crash_mid_spill_recovers_without_leaks() {
    let dir = std::env::temp_dir().join(format!("funcx-faults-spool-{}", funcx::Uuid::new()));
    let owner = EndpointId::new();
    let cfg = TieredConfig {
        mem_high_watermark: 16 * 1024, // one 12 KB frame resident at most
        default_ttl_s: 0.0,
        spool_dir: Some(dir.clone()),
    };
    let spilled_bytes = frame(0x88, 12 << 10);
    let (spilled_ref, resident_ref) = {
        let s = TieredStore::new(owner, cfg.clone()).unwrap();
        let spilled = s.put("chain:spilled", spilled_bytes.clone(), 0.0).unwrap();
        // The second put pushes the first to disk (background spiller)
        // and stays in memory.
        let resident = s.put("chain:resident", frame(0x99, 12 << 10), 0.0).unwrap();
        assert!(s.settle(Duration::from_secs(10)), "spill must complete before the crash");
        assert_eq!(s.tier_of("chain:spilled"), Some(funcx::datastore::Tier::Disk));
        assert_eq!(s.tier_of("chain:resident"), Some(funcx::datastore::Tier::Memory));
        std::mem::forget(s); // crash: no Drop, no cleanup
        (spilled, resident)
    };
    // Interrupted spill: a frame file the manifest never recorded.
    std::fs::write(dir.join("torn.0123456789abcdef"), [0u8; 64]).unwrap();

    let recovered = Arc::new(TieredStore::recover(owner, cfg).unwrap());
    // No leaked files after recovery: exactly the one readopted frame
    // remains (plus the manifest) — the torn orphan was reclaimed.
    // (Checked before any resolve: a resolve may promote the frame back
    // to memory and legitimately retire the spool file.)
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    assert_eq!(names.len(), 2, "spool must hold one frame + manifest, got {names:?}");
    assert!(names.iter().any(|n| n.starts_with("chain_spilled")), "{names:?}");
    assert!(names.contains(&"spool.manifest".to_string()), "{names:?}");
    // Byte-identical readopt under the old epoch: the in-flight ref
    // resolves as if the crash never happened.
    let got = recovered.resolve(&spilled_ref, 0.0).unwrap();
    assert_eq!(got.as_slice(), spilled_bytes.as_slice());
    // The memory-tier frame died with the process: typed NotFound.
    assert!(matches!(recovered.resolve(&resident_ref, 0.0), Err(Error::NotFound(_))));

    // And the whole fault still fails a *task* cleanly, not just a
    // direct resolve — with a trace closing on the typed NotFound.
    let fabric = Arc::new(DataFabric::new(recovered));
    let (r, trace) = run_ref_task_traced(fabric, Arc::new(WallClock::new()), resident_ref);
    assert!(failure_message(&r).contains("not found"), "got: {}", failure_message(&r));
    assert_fault_trace(&trace, "NotFound");

    std::fs::remove_dir_all(&dir).unwrap();
}

/// Fault: crash mid-manifest-compaction. The manifest is an append-only
/// log compacted via write-to-temp + rename; a crash that leaves a
/// half-written `.tmp` (and a torn final append in the live log) must
/// not cost a single committed frame: recovery replays the intact log,
/// readopts every spilled frame byte-identical, and ignores the temp.
#[test]
fn crash_mid_manifest_compaction_recovers_all_frames() {
    let dir = std::env::temp_dir().join(format!("funcx-faults-compact-{}", funcx::Uuid::new()));
    let owner = EndpointId::new();
    let cfg = TieredConfig {
        mem_high_watermark: 0, // everything spills; every spill appends
        default_ttl_s: 0.0,
        spool_dir: Some(dir.clone()),
    };
    let refs: Vec<(DataRef, Buffer)> = {
        let s = TieredStore::new(owner, cfg.clone()).unwrap();
        let refs: Vec<(DataRef, Buffer)> = (0..8)
            .map(|i| {
                let f = frame(0x10 + i as u8, 4 << 10);
                (s.put(&format!("chain:k{i}"), f.clone(), 0.0).unwrap(), f)
            })
            .collect();
        assert!(s.settle(Duration::from_secs(10)), "all spills must commit");
        std::mem::forget(s); // crash: no Drop, no cleanup
        refs
    };
    // The crash struck mid-compaction: a partial snapshot that never
    // renamed over the live log…
    std::fs::write(dir.join("spool.manifest.tmp"), "v2 1\n+ dead-partial").unwrap();
    // …and mid-append: a torn final record on the live log itself.
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join("spool.manifest"))
            .unwrap();
        f.write_all(b"+ 746f726e 12").unwrap(); // no checksum/expiry/newline
    }

    let recovered = Arc::new(TieredStore::recover(owner, cfg).unwrap());
    assert_eq!(recovered.len(), 8, "every committed spill survives the torn log");
    for (r, bytes) in &refs {
        let got = recovered.resolve(r, 0.0).unwrap();
        assert_eq!(got.as_slice(), bytes.as_slice(), "byte-identical after compaction crash");
    }
    // And the whole fault still fails nothing at the task level: a
    // by-ref task over a recovered frame succeeds.
    let fabric = Arc::new(DataFabric::new(recovered));
    let ok = run_ref_task(fabric, Arc::new(WallClock::new()), refs[0].0.clone());
    assert_eq!(ok.state, TaskState::Success);

    std::fs::remove_dir_all(&dir).unwrap();
}

/// A spool whose writes *panic* on demand — the spiller-thread-crash
/// harness. Reads keep working so the disk tier stays readable while
/// new spills die.
struct DyingSpool {
    inner: DiskBackend,
    dead: AtomicBool,
}

impl StoreBackend for DyingSpool {
    fn name(&self) -> &'static str {
        "dying-fake"
    }
    fn put(&self, key: &str, frame: &Buffer) -> funcx::Result<()> {
        self.inner.put(key, frame)
    }
    fn get(&self, key: &str) -> funcx::Result<Option<Buffer>> {
        self.inner.get(key)
    }
    fn remove(&self, key: &str) -> funcx::Result<bool> {
        StoreBackend::remove(&self.inner, key)
    }
}

impl SpoolStore for DyingSpool {
    fn put_entry(
        &self,
        key: &str,
        frame: &Buffer,
        expires_at: Option<funcx::common::time::Time>,
    ) -> funcx::Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            panic!("injected spiller crash mid-storm");
        }
        self.inner.put_entry(key, frame, expires_at)
    }
}

/// Fault: the spiller's spool writes start *panicking* (not erroring)
/// mid put-storm. The store must degrade to memory-only exactly as for
/// an erroring spool — typed `Error::Overloaded` sheds bounding the
/// memory tier at shed_factor × watermark, every live key still
/// readable (including the pre-crash disk tier), never a hang, and the
/// panic never escapes to a caller. After a process crash, recovery
/// readopts the pre-crash spill byte-identical.
#[test]
fn spiller_crash_mid_storm_sheds_typed_and_recovers() {
    const WM: usize = 4 << 10;
    let dir = std::env::temp_dir().join(format!("funcx-faults-storm-{}", funcx::Uuid::new()));
    let owner = EndpointId::new();
    let cfg = TieredConfig {
        mem_high_watermark: WM,
        default_ttl_s: 0.0,
        spool_dir: Some(dir.clone()),
    };
    let spool = Arc::new(DyingSpool {
        inner: DiskBackend::new(dir.clone()).unwrap(),
        dead: AtomicBool::new(false),
    });
    spool.inner.set_epoch(42).unwrap();
    let s = TieredStore::with_spool_for_tests(owner, cfg.clone(), spool.clone())
        .with_shed_factor(4);
    let limit = 4 * WM;

    // Healthy phase: one frame committed to the disk tier pre-crash.
    let spilled = frame(0x21, 6 << 10);
    s.put("storm:spilled", spilled.clone(), 0.0).unwrap();
    s.put("storm:hot", frame(0x22, 2 << 10), 0.0).unwrap();
    assert!(s.settle(Duration::from_secs(10)), "healthy spill must commit");
    assert_eq!(s.tier_of("storm:spilled"), Some(funcx::datastore::Tier::Disk));

    // Kill the spiller: every spool write from here on panics. Fill
    // past the watermark so the spiller attempts (and dies).
    spool.dead.store(true, Ordering::SeqCst);
    let mut accepted: Vec<String> = vec!["storm:hot".into()];
    for i in 0..8u32 {
        let key = format!("storm:k{i}");
        s.put(&key, frame(i as u8, 1 << 10), 0.0).unwrap();
        accepted.push(key);
    }
    let t0 = std::time::Instant::now();
    while s.stats.spill_errors.load(Ordering::Relaxed) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "the crashed spiller must surface a spill error, not kill the store"
        );
        std::thread::yield_now();
    }

    // The storm: occupancy stays bounded at the shed limit, over-limit
    // puts are refused with the typed backpressure error, and no put
    // ever panics or hangs. The width is perturbed by the kill-matrix
    // seed so each CI leg sheds a different number of puts.
    let mut shed = 0usize;
    let storm_end = 64 + (chaos_seed() % 32) as u32;
    for i in 8..storm_end {
        let key = format!("storm:k{i}");
        match s.put(&key, frame(i as u8, 1 << 10), 0.0) {
            Ok(_) => accepted.push(key),
            Err(Error::Overloaded(m)) => {
                assert!(m.contains("shed"), "{m}");
                shed += 1;
            }
            Err(other) => panic!("expected Overloaded, got {other:?}"),
        }
        assert!(s.mem_bytes() <= limit, "memory tier exceeded the shed limit");
    }
    assert!(shed > 0, "a dead spiller must shed eventually");
    assert_eq!(s.stats.shed_puts.load(Ordering::Relaxed), shed as u64);

    // Degraded memory-only mode: every accepted key is still readable,
    // and so is the pre-crash disk tier (reads don't cross the dead
    // write path).
    for key in &accepted {
        s.get(key, 0.0).unwrap();
    }
    assert_eq!(s.get("storm:spilled", 0.0).unwrap().as_slice(), spilled.as_slice());

    // Process crash on top of the dead spiller: no Drop, no cleanup.
    std::mem::forget(s);

    // Recovery readopts the one committed spill byte-identical; the
    // memory-tier storm keys died with the process.
    let recovered = TieredStore::recover(owner, cfg).unwrap();
    assert_eq!(recovered.len(), 1, "only the committed spill survives the crash");
    let got = recovered.get("storm:spilled", 0.0).unwrap();
    assert_eq!(got.as_slice(), spilled.as_slice(), "readopt must be byte-identical");
    assert!(matches!(recovered.get("storm:hot", 0.0), Err(Error::NotFound(_))));

    drop(recovered);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Decommission lifecycle (§4.1 churn): retiring an endpoint through
/// the orderly path must leave no orphan spool files, no dangling store
/// advertisement, and every in-flight (unretrieved) result ref must
/// keep resolving by failing over to the replica the service placed on
/// a surviving endpoint.
#[test]
fn decommission_leaves_no_orphans_and_fails_over_inflight_refs() {
    let dir = std::env::temp_dir().join(format!("funcx-faults-decomm-{}", funcx::Uuid::new()));
    let clock: Arc<WallClock> = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 4096,
        replication_factor: 1,
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
    let e = svc.register_endpoint(&tok, "retiring", "").unwrap();
    let e2 = svc.register_endpoint(&tok, "survivor", "").unwrap();

    // Retiring endpoint: a spool-backed store with a watermark below
    // the result size, so the frame spills to disk before retirement.
    let store_e = Arc::new(
        TieredStore::new(
            e,
            TieredConfig {
                mem_high_watermark: 16 * 1024,
                default_ttl_s: 0.0,
                spool_dir: Some(dir.clone()),
            },
        )
        .unwrap(),
    );
    let (fwd_e, agent_e) = link();
    let h_e = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 1,
            max_result_bytes: 4096, // force the result by-ref
            ..Default::default()
        })
        .fabric(Arc::new(DataFabric::new(store_e.clone())))
        .clock(clock.clone())
        .heartbeat_period(0.05)
        .start(agent_e);
    let fh_e = svc.connect_endpoint(e, fwd_e).unwrap();

    // Survivor endpoint: advertises the store the replica lands in.
    let store_e2 = Arc::new(TieredStore::new(e2, TieredConfig::default()).unwrap());
    let (fwd_e2, agent_e2) = link();
    let h_e2 = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
        .fabric(Arc::new(DataFabric::new(store_e2.clone())))
        .clock(clock.clone())
        .heartbeat_period(0.05)
        .start(agent_e2);
    let fh_e2 = svc.connect_endpoint(e2, fwd_e2).unwrap();

    // Both stores must be advertised before the result is stored, or
    // there is nowhere to replicate to.
    let t0 = std::time::Instant::now();
    while svc.registry.advertised_store(e).is_none()
        || svc.registry.advertised_store(e2).is_none()
    {
        assert!(t0.elapsed() < Duration::from_secs(5), "advertisements must arrive");
        std::thread::yield_now();
    }

    // Run one task on the retiring endpoint; its ~64 KB result is
    // offloaded into the retiring store and replicated to the survivor.
    // The size is perturbed by the kill-matrix seed (always above the
    // 4 KB by-ref thresholds, so the lifecycle is identical per leg).
    let input = Value::Bytes(vec![0x5C; 64 * 1024 + (chaos_seed() % 16) * 1024]);
    let r = svc.submit(&tok, f, e, &input).unwrap();
    let rref = svc.wait_result_ref(r.task, Duration::from_secs(10)).unwrap();
    assert_eq!(rref.owner, e, "the result lives in the retiring endpoint's store");
    assert_eq!(rref.replicas, vec![e2], "the stored record carries the replica set");
    assert!(
        store_e2.get(&rref.replica_key(), clock.now()).is_ok(),
        "the replica frame must sit in the survivor's store"
    );

    // Retire the endpoint while the result is still unretrieved.
    fh_e.decommission();
    h_e.join();

    // No dangling advertisement, endpoint Offline, store purged.
    assert!(svc.registry.advertised_store(e).is_none(), "advertisement must be withdrawn");
    assert_eq!(svc.registry.endpoint(e).unwrap().status, EndpointStatus::Offline);
    assert!(store_e.is_empty(), "decommission must purge the retiring store");
    assert!(Counters::get(&svc.counters.frames_drained) >= 1);
    // No orphan spool files: only the manifest survives the purge.
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|x| x.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|n| !n.starts_with("spool.manifest"))
        .collect();
    assert!(leftovers.is_empty(), "orphan spool files after decommission: {leftovers:?}");

    // The in-flight ref still resolves: drop the service fabric's
    // cached copy (warmed during replication) to force the ladder, then
    // fail over to the survivor's replica.
    svc.fabric.reclaim(&rref);
    let got = svc.fabric.resolve(&rref, clock.now()).unwrap();
    assert_eq!(unpack(&got).unwrap(), input, "failover must serve the original bytes");
    assert!(Counters::get(&svc.counters.failover_resolutions) >= 1);
    assert_eq!(Counters::get(&svc.counters.replicas_created), 1);

    // And the user-visible retrieval path works end to end.
    assert_eq!(svc.get_result(r.task).unwrap(), Some(input));

    // The whole churn episode is visible in the task's flight trace:
    // the decommission drain re-homed its result frame, and the
    // post-retirement resolve failed over to the replica.
    let trace = svc.trace(r.task).expect("service-submitted tasks are traced by default");
    assert!(
        trace.events.iter().any(|e| matches!(e.kind, TraceKind::FrameDrained { .. })),
        "trace must show the decommission drain\n{}",
        trace.render()
    );
    assert!(
        trace.events.iter().any(|e| matches!(e.kind, TraceKind::ReplicaFailover { .. })),
        "trace must show the replica failover\n{}",
        trace.render()
    );

    fh_e2.shutdown();
    h_e2.join();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The harness's own sanity: an unfaulted by-ref task succeeds, so the
/// failures above are the faults' doing, not the harness's.
#[test]
fn unfaulted_ref_task_succeeds() {
    let fabric = Arc::new(DataFabric::new(store()));
    let input = Value::Bytes(vec![0xAA; 4 << 10]);
    let dref = fabric.put("task-input:ok", pack(&input, 0).unwrap(), 0.0).unwrap();
    let r = run_ref_task(fabric, Arc::new(WallClock::new()), dref);
    assert_eq!(r.state, TaskState::Success);
    assert_eq!(unpack(&r.output).unwrap(), input);
}
