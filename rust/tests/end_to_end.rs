//! Integration tests over the live engine: multi-endpoint topologies,
//! failure injection, artifact payloads, auth enforcement, and data
//! staging — the compositions module-level unit tests don't cover.

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::{Payload, TaskState};
use funcx::containers::{ContainerTech, SystemProfile};
use funcx::data::InMemoryChannel;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::provider::SimProvider;
use funcx::routing::RoundRobin;
use funcx::runtime::PjrtRuntime;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;
use funcx::transfer::{GlobusFile, TransferService, TransferStatus};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Two endpoints, one service: tasks route to the endpoint the user
/// picked, results come back independently (the federation contract).
#[test]
fn two_endpoints_isolated_queues() {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);

    let mut handles = Vec::new();
    let mut eps = Vec::new();
    for name in ["theta", "cori"] {
        let ep = fc.register_endpoint(name, "").unwrap();
        let (fwd, agent_side) = link();
        let agent = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
            .heartbeat_period(0.05)
            .start(agent_side);
        let fh = svc.connect_endpoint(ep, fwd).unwrap();
        handles.push((agent, fh));
        eps.push(ep);
    }
    let f = fc.register_function("echo", Payload::Echo).unwrap();
    // Interleave submissions across endpoints.
    let mut tasks = Vec::new();
    for i in 0..40 {
        let ep = eps[i % 2];
        tasks.push(fc.run(f, ep, &Value::Int(i as i64)).unwrap());
    }
    for (i, t) in tasks.iter().enumerate() {
        assert_eq!(
            fc.get_result(*t, Duration::from_secs(15)).unwrap(),
            Value::Int(i as i64)
        );
    }
    for (agent, fh) in handles {
        fh.shutdown();
        agent.join();
    }
}

/// Artifact payloads through the full stack (PJRT on the worker).
#[test]
fn artifact_payloads_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("local", "").unwrap();
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
        .runtime(Arc::new(PjrtRuntime::load_dir(&dir).unwrap()))
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();

    let f = fc.register_function("reduce", Payload::Artifact("reducer".into())).unwrap();
    let ids: Vec<i32> = (0..4096).map(|i| (i % 8) as i32).collect();
    let input = Value::map([
        ("ids", Value::I32s(ids)),
        ("vals", Value::F32s(vec![2.0; 4096])),
    ]);
    let t = fc.run(f, ep, &input).unwrap();
    let out = fc.get_result(t, Duration::from_secs(60)).unwrap();
    match out {
        Value::List(parts) => match &parts[0] {
            Value::F32s(sums) => {
                for b in 0..8 {
                    assert!((sums[b] - 1024.0).abs() < 1e-3);
                }
                assert!(sums[8..].iter().all(|v| *v == 0.0));
            }
            _ => panic!("bad output"),
        },
        _ => panic!("bad result"),
    }

    // Malformed artifact input fails gracefully (Failed, not hang).
    let bad = fc.run(f, ep, &Value::Null).unwrap();
    let err = svc.wait_result(bad, Duration::from_secs(30));
    assert!(err.is_err());
    assert_eq!(svc.task_state(bad).unwrap(), TaskState::Failed);

    fh.shutdown();
    agent.join();
}

/// §4.7: tokens without scopes are rejected across every API.
#[test]
fn auth_is_enforced_everywhere() {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_admin, admin_tok) = svc.bootstrap_user("admin");
    let limited = svc.auth.register_identity("limited");
    let run_only = svc
        .auth
        .issue_token(limited, &[funcx::auth::Scope::RunFunction], 3600.0, 0.0)
        .unwrap();

    let fc_admin = FuncXClient::new(svc.clone(), admin_tok);
    let fc_limited = FuncXClient::new(svc.clone(), run_only);

    // limited cannot register functions or endpoints.
    assert!(fc_limited.register_function("f", Payload::Noop).is_err());
    assert!(fc_limited.register_endpoint("e", "").is_err());

    // limited cannot run admin's unshared function.
    let f = fc_admin.register_function("secret", Payload::Noop).unwrap();
    let ep = fc_admin.register_endpoint("ep", "").unwrap();
    assert!(fc_limited.run(f, ep, &Value::Null).is_err());

    // sharing the function is not enough: the endpoint must be shared too.
    svc.auth.grant_function(f, limited);
    assert!(fc_limited.run(f, ep, &Value::Null).is_err());
    svc.auth.grant_endpoint(ep, limited);
    assert!(fc_limited.run(f, ep, &Value::Null).is_ok());
}

/// §4.4/§6.3: batch-scheduler provider with queue delays + elastic
/// scale-out, then scale-in after idle.
#[test]
fn elastic_lifecycle_with_batch_provider() {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("cluster", "").unwrap();
    let (fwd, agent_side) = link();
    // Kubernetes-ish provider: ~2s pod starts — fast enough for a test,
    // slow enough to exercise the pending-node path.
    let agent = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 0,
            max_nodes: 2,
            workers_per_node: 2,
            strategy_period_s: 0.02,
            node_idle_timeout_s: 0.3,
            tasks_per_node_scaling: 2,
            ..Default::default()
        })
        .provider(Box::new(SimProvider::kubernetes(7)))
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = fc.register_function("noop", Payload::Noop).unwrap();

    let tasks: Vec<_> = (0..8).map(|_| fc.run(f, ep, &Value::Null).unwrap()).collect();
    for t in &tasks {
        fc.get_result(*t, Duration::from_secs(30)).unwrap();
    }
    let provisioned = agent.stats.nodes_provisioned.load(std::sync::atomic::Ordering::Relaxed);
    assert!(provisioned >= 1, "scale-out must have happened");

    // Idle long enough for scale-in.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while agent.stats.nodes_released.load(std::sync::atomic::Ordering::Relaxed) == 0
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        agent.stats.nodes_released.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "idle nodes must be released (§6.3)"
    );
    fh.shutdown();
    agent.join();
}

/// Alternative scheduler (round-robin) works through the live agent.
#[test]
fn round_robin_scheduler_live() {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("local", "").unwrap();
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 2, workers_per_node: 1, ..Default::default() })
        .scheduler(Box::new(RoundRobin::default()))
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = fc.register_function("echo", Payload::Echo).unwrap();
    let inputs: Vec<Value> = (0..20).map(Value::Int).collect();
    let tasks = fc.run_batch(f, ep, &inputs).unwrap();
    assert_eq!(fc.get_batch_results(&tasks, Duration::from_secs(30)).unwrap(), inputs);
    fh.shutdown();
    agent.join();
}

/// §5: staging + intra-endpoint data ops compose — stage a "file" via the
/// transfer service, have workers move data through the endpoint store.
#[test]
fn data_staging_and_intra_endpoint_ops() {
    // Inter-endpoint staging (Globus-like).
    let ts = TransferService::new();
    let src = ts.register_endpoint("beamline", 1e9, 0.5);
    let dst = ts.register_endpoint("hpc", 1e9, 0.5);
    let file = GlobusFile { endpoint: src, path: "/raw/a.h5".into(), size_bytes: 50_000_000 };
    let tid = ts.submit(&file, dst, "/scratch/a.h5", 0.0).unwrap();
    let done = ts.completion_time(tid).unwrap();
    assert!(done > 0.5 && done < 5.0, "50MB over 1GB/s + setup: got {done}");
    assert_eq!(ts.status(tid, done).unwrap(), TransferStatus::Succeeded);

    // Intra-endpoint: workers put/get through the shared store (§5.2).
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("hpc", "").unwrap();
    let store = Arc::new(InMemoryChannel::default());
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
        .data_channel(store.clone())
        .profile(SystemProfile::Theta, ContainerTech::Singularity)
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let dataop = fc.register_function("dataop", Payload::DataOp).unwrap();

    // Producer task writes; consumer task reads (Listing 3's pattern).
    let put = Value::map([
        ("op", Value::Str("put".into())),
        ("key", Value::Str("stage/x".into())),
        ("data", Value::Bytes(vec![7; 1024])),
    ]);
    let t1 = fc.run(dataop, ep, &put).unwrap();
    fc.get_result(t1, Duration::from_secs(15)).unwrap();
    let get = Value::map([
        ("op", Value::Str("get".into())),
        ("key", Value::Str("stage/x".into())),
    ]);
    let t2 = fc.run(dataop, ep, &get).unwrap();
    assert_eq!(
        fc.get_result(t2, Duration::from_secs(15)).unwrap(),
        Value::Bytes(vec![7; 1024])
    );
    fh.shutdown();
    agent.join();
}

/// Task conservation under repeated agent churn: every submitted task
/// ends terminal (Success after reconnect, or Abandoned past the
/// re-dispatch budget) — none lost, none duplicated.
#[test]
fn churn_conserves_tasks() {
    let mut cfg = ServiceConfig::default();
    cfg.heartbeat_period_s = 0.05;
    cfg.heartbeat_misses_allowed = 1;
    let svc = Arc::new(FuncXService::new(cfg));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("flaky", "").unwrap();
    let f = fc.register_function("noop", Payload::Noop).unwrap();

    // Submit before any agent exists.
    let tasks: Vec<_> = (0..30).map(|_| fc.run(f, ep, &Value::Null).unwrap()).collect();

    // Two kill/reconnect cycles, then a healthy agent.
    for round in 0..2 {
        let (fwd, agent_side) = link();
        agent_side.sever();
        drop(agent_side);
        let fh = svc.connect_endpoint(ep, fwd).unwrap();
        std::thread::sleep(Duration::from_millis(300 + round * 100));
        fh.shutdown();
    }
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 4, ..Default::default() })
        .heartbeat_period(0.02)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();

    let mut success = 0;
    let mut abandoned = 0;
    for t in &tasks {
        match svc.wait_result(*t, Duration::from_secs(30)) {
            Ok(_) => success += 1,
            Err(funcx::Error::TaskFailed(_)) => abandoned += 1,
            Err(e) => panic!("unexpected terminal state: {e}"),
        }
    }
    assert_eq!(success + abandoned, 30, "every task must reach a terminal state");
    assert!(success > 0, "healthy reconnect must complete the queue");
    fh.shutdown();
    agent.join();
}
