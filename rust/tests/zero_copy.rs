//! Pointer-identity pins for the zero-copy task lifecycle: from the
//! moment a task frame is popped off its queue, dispatching it through
//! the forwarder's ack cache, the link, and the manager's worker queue
//! must never deep-copy the task record or its payload body. The sibling
//! `alloc_discipline` test binary pins the allocation counts; this one
//! pins allocation *identity* (`Buffer::same_allocation`, `Arc::ptr_eq`,
//! `Arc::strong_count`).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use funcx::common::ids::{EndpointId, FunctionId, UserId};
use funcx::common::task::{Payload, Task, TaskResult, TaskState};
use funcx::common::time::WallClock;
use funcx::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
use funcx::endpoint::{link, Downstream, Manager, ManagerCtx};
use funcx::metrics::LatencyBreakdown;
use funcx::runtime::PayloadExecutor;
use funcx::serialize::{pack, Buffer, Value, Wire};
use funcx::store::{KvStore, TaskQueue};

fn mk_task(payload: Payload, input: Buffer) -> Task {
    Task::new(FunctionId::new(), EndpointId::new(), UserId::new(), None, payload, input)
}

/// A decoded task's input is a borrowed view into the frame it came
/// from — same allocation, not a copy.
#[test]
fn task_input_borrows_its_frame() {
    let input = pack(&Value::Bytes(vec![7u8; 4096]), 0).unwrap();
    let task = mk_task(Payload::Echo, input.clone());
    let frame = task.to_buffer();
    let back = Task::from_buffer(&frame).unwrap();
    assert!(back.input.same_allocation(&frame), "input must be a view into the frame");
    assert!(
        back.input.alloc_len() > back.input.len(),
        "a deep copy would have an exact-size allocation"
    );
    assert_eq!(back.input, input);
}

/// Same invariant on the return path: a decoded result's output borrows
/// the result frame (what `get_result` pulls out of the KV store).
#[test]
fn result_output_borrows_its_frame() {
    let output = pack(&Value::Bytes(vec![9u8; 2048]), 0).unwrap();
    let r = TaskResult {
        task: funcx::common::ids::TaskId::new(),
        state: TaskState::Success,
        output: output.clone(),
        output_ref: None,
        exec_time_s: 0.5,
        cold_start: false,
    };
    let frame = r.to_buffer();
    let back = TaskResult::from_buffer(&frame).unwrap();
    assert!(back.output.same_allocation(&frame));
    assert_eq!(back.output, output);
}

/// The `"rref"` trailer field survives the wire, and a by-ref result
/// frame under hostile `body_len` values errors out instead of
/// panicking or mis-decoding (the same contract the facade pins for
/// plain frames).
#[test]
fn rref_frame_roundtrips_and_rejects_hostile_body_len() {
    let dref = funcx::datastore::DataRef {
        owner: EndpointId::new(),
        epoch: 9,
        key: "task-result:chain".into(),
        size: 1 << 20,
        checksum: 0xABCD_EF01,
        replicas: Vec::new(),
    };
    let r = TaskResult {
        task: funcx::common::ids::TaskId::new(),
        state: TaskState::Success,
        output: Buffer::empty(),
        output_ref: Some(dref.clone()),
        exec_time_s: 0.25,
        cold_start: false,
    };
    let frame = r.to_buffer();
    let back = TaskResult::from_buffer(&frame).unwrap();
    assert_eq!(back.output_ref, Some(dref));
    assert_eq!(back.output.len(), 0);

    let bytes = frame.to_vec();
    // body_len claims reaching past the frame must all error.
    for claimed in [u32::MAX, u32::MAX - 9, 1u32 << 30, bytes.len() as u32] {
        let mut raw = bytes.clone();
        raw[6..10].copy_from_slice(&claimed.to_le_bytes());
        assert!(
            TaskResult::from_buffer(&Buffer::from_vec(raw)).is_err(),
            "claimed body_len {claimed} must be rejected"
        );
    }
    // A clobbered magic byte is rejected before anything decodes.
    let mut raw = bytes.clone();
    raw[0] = 0x00;
    assert!(TaskResult::from_buffer(&Buffer::from_vec(raw)).is_err());
}

/// Popping a typed queue yields tasks whose payload still lives in the
/// queue frame's allocation (the store hands out refcounted handles).
#[test]
fn queue_pop_yields_borrowed_payload() {
    let kv = KvStore::new();
    let q: TaskQueue<Task> = TaskQueue::new(kv, "ep:tasks");
    let input = pack(&Value::Bytes(vec![3u8; 1024]), 0).unwrap();
    let task = mk_task(Payload::Echo, input.clone());
    q.push(&task).unwrap();
    let popped = q.pop().unwrap().unwrap();
    assert_eq!(popped.input, input);
    assert!(
        popped.input.alloc_len() > popped.input.len(),
        "popped input must be a view into the popped frame, not a copy"
    );
}

/// THE dispatch-path pin (acceptance criterion): pop a task from its
/// queue, cache it in-flight, frame it down the link, enqueue it at a
/// manager — every hop shares ONE `Task` allocation (whose input is a
/// view into the queue frame), verified by pointer identity and live
/// refcounts while the worker executes.
#[test]
fn dispatch_forwarder_link_manager_is_zero_copy() {
    // Submit side: serialize into the queue once.
    let kv = KvStore::new();
    let q: TaskQueue<Task> = TaskQueue::new(kv, "ep:tasks");
    let input = pack(&Value::Bytes(vec![5u8; 8192]), 0).unwrap();
    q.push(&mk_task(Payload::Sleep(0.3), input)).unwrap();

    // Forwarder hop: pop + wrap once, cache in-flight, send on the link.
    let popped = q.pop().unwrap().unwrap();
    let frame_view = popped.input.clone();
    let in_flight = Arc::new(popped); // §4.1 ack-cache handle
    let (fwd, agent) = link();
    assert!(fwd.send(Downstream::Tasks(vec![in_flight.clone()])));

    // Agent hop: the received task IS the cached one.
    let received = match agent.recv_timeout(Duration::from_millis(200)) {
        Some(Downstream::Tasks(mut ts)) => ts.pop().unwrap(),
        other => panic!("unexpected {other:?}"),
    };
    assert!(Arc::ptr_eq(&received, &in_flight), "link must move handles, not clone tasks");
    assert!(received.input.same_allocation(&frame_view));

    // Manager hop: enqueue the same handle; while the worker sleeps the
    // allocation is shared by ack cache + this test + the worker.
    let (tx, rx) = channel();
    let ctx = ManagerCtx {
        executor: Arc::new(PayloadExecutor::bare()),
        results: tx,
        wake: Arc::new(funcx::common::sync::Notify::new()),
        result_batch: 1,
        fabric: None,
        endpoint: None,
        max_result_bytes: 10 * 1024 * 1024,
        clock: Arc::new(WallClock::new()),
        latency: Arc::new(LatencyBreakdown::new()),
        recorder: funcx::metrics::FlightRecorder::disabled(),
        start_model: TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None),
        cold_start_scale: 0.001,
        pipeline_depth: 1,
    };
    let m = Manager::spawn(1, 600.0, ctx, 1);
    m.enqueue(vec![received]);
    std::thread::sleep(Duration::from_millis(100));
    assert!(
        Arc::strong_count(&in_flight) >= 2,
        "worker must execute the shared allocation, not a copy"
    );
    let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(batch[0].state, TaskState::Success);
    m.shutdown();
    assert_eq!(Arc::strong_count(&in_flight), 1, "all hops released the shared handle");
}

/// Buffer clones are refcount bumps on one allocation.
#[test]
fn buffer_clone_is_refcount_not_copy() {
    let b = pack(&Value::Bytes(vec![1u8; 65536]), 0).unwrap();
    let clones: Vec<Buffer> = (0..64).map(|_| b.clone()).collect();
    assert!(clones.iter().all(|c| c.same_allocation(&b)));
    assert_eq!(b.ref_count(), 65);
}
