//! Integration pins for the observability plane: the cross-shard
//! flight-recorder acceptance trace, the snapshot-only task
//! conservation invariant, and the bounded-memory soak for the
//! latency breakdown and the recorder rings.

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::ids::TaskId;
use funcx::common::task::Payload;
use funcx::common::time::WallClock;
use funcx::datastore::{DataFabric, Tier, TieredConfig, TieredStore};
use funcx::endpoint::{link, EndpointBuilder};
use funcx::metrics::{FlightRecorder, LatencyBreakdown, TraceKind, MAX_TRACKED_PER_STRIPE};
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

/// THE flight-recorder acceptance pin: a cross-shard A→B→C ref chain
/// with an injected replica failover assembles into a SINGLE trace
/// (B's) whose events span two service shards, two physical endpoints,
/// and the data fabric — with the `ReplicaFailover` event present.
///
/// Topology: A runs on the owner endpoint and its oversized result is
/// offloaded into the owner's store (where the background spiller
/// pushes it to the disk tier — a key-only `Spilled` event). The owner
/// is then decommissioned: the frame is re-homed to the survivor (a
/// key-only `FrameDrained` event on the owner's shard) and the peer
/// link dropped. B, submitted by ref to the survivor, resolves A's
/// output through its own fabric's replica scan — the failover — and C
/// closes the chain. Assembling B's timeline joins the anonymous
/// spill/drain events back in by ref key, which is exactly what makes
/// the one trace span both endpoints and both shards.
#[test]
fn cross_shard_chain_with_failover_assembles_one_trace() {
    let clock = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 4096, // force A's input by-ref
        service_shards: 4,
        replication_factor: 1,
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();

    // Owner and survivor must hash to DIFFERENT shards so the chain's
    // trace provably crosses the shard split (endpoint ids are random;
    // redraw until they differ).
    let map = svc.shard_map();
    let e_owner = svc.register_endpoint(&tok, "owner", "").unwrap();
    let mut e_survivor = svc.register_endpoint(&tok, "survivor", "").unwrap();
    let mut draws = 0;
    while map.shard_for_endpoint(e_survivor) == map.shard_for_endpoint(e_owner) {
        draws += 1;
        assert!(draws < 256, "could not draw a distinct shard in 256 tries");
        e_survivor = svc.register_endpoint(&tok, &format!("survivor{draws}"), "").unwrap();
    }

    // Owner stack. The tiny memory watermark forces A's 256 KB result
    // frame to spill to the disk tier — the background spiller records
    // a key-only `Spilled` event on `store-<owner>`.
    let store1 = Arc::new(
        TieredStore::new(
            e_owner,
            TieredConfig { mem_high_watermark: 64 * 1024, default_ttl_s: 0.0, spool_dir: None },
        )
        .unwrap(),
    );
    let (fwd1, agent1) = link();
    let h1 = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 2,
            max_result_bytes: 4096, // force results by-ref
            ..Default::default()
        })
        .fabric(Arc::new(DataFabric::new(store1.clone())))
        .clock(clock.clone())
        .recorder(svc.recorder.clone())
        .heartbeat_period(0.05)
        .start(agent1);
    let fh1 = svc.connect_endpoint(e_owner, fwd1).unwrap();

    // Survivor stack: B and C execute here.
    let store2 = Arc::new(TieredStore::new(e_survivor, TieredConfig::default()).unwrap());
    let fabric2 = Arc::new(DataFabric::new(store2.clone()));
    let (fwd2, agent2) = link();
    let h2 = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 2,
            max_result_bytes: 4096,
            ..Default::default()
        })
        .fabric(fabric2.clone())
        .clock(clock)
        .recorder(svc.recorder.clone())
        .heartbeat_period(0.05)
        .start(agent2);
    let fh2 = svc.connect_endpoint(e_survivor, fwd2).unwrap();

    // Replication (and the later drain) need both stores advertised
    // before A's result lands.
    let t0 = std::time::Instant::now();
    while svc.registry.advertised_store(e_owner).is_none()
        || svc.registry.advertised_store(e_survivor).is_none()
    {
        assert!(t0.elapsed() < Duration::from_secs(5), "advertisements must arrive");
        std::thread::yield_now();
    }

    // A on the owner: 256 KB in, 256 KB out — the output offloaded
    // into the owner's store and replicated to the survivor.
    let payload = Value::Bytes(vec![0x42; 256 * 1024]);
    let a = svc.submit(&tok, f, e_owner, &payload).unwrap();
    let ref_a = svc.wait_result_ref(a.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_a.owner, e_owner);
    assert_eq!(ref_a.replicas, vec![e_survivor], "A's ref must list the replica holder");
    let key_a = ref_a.key.clone();

    // Wait for the spiller: A's frame exceeds the watermark, so it must
    // land on the disk tier (recording the key-only Spilled event).
    assert!(store1.settle(Duration::from_secs(10)), "spill must complete");
    assert_eq!(store1.tier_of(&key_a), Some(Tier::Disk));

    // Inject the failure: kill the owner's agent, then decommission the
    // endpoint — the drain re-homes A's frame to the survivor (key-only
    // FrameDrained on the owner's shard) and severs the peer links.
    fh1.shutdown();
    h1.join();
    let drained = svc.decommission_endpoint(e_owner).unwrap();
    assert!(drained >= 1, "A's result frame must be re-homed");

    // B on the survivor, by ref: its input resolve cannot reach the
    // dead owner and must fail over to the replica copy. C closes the
    // chain and round-trips the payload.
    let b = svc.submit_by_ref(&tok, f, e_survivor, &ref_a).unwrap();
    let ref_b = svc.wait_result_ref(b.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_b.owner, e_survivor);
    let c = svc.submit_by_ref(&tok, f, e_survivor, &ref_b).unwrap();
    let out = svc.wait_result(c.task, Duration::from_secs(10)).unwrap();
    assert_eq!(out, payload, "the chain must survive the owner's death");

    // THE pin: one assembled trace spanning shards, endpoints, fabric.
    let trace = svc.trace(b.task).expect("B must have an assembled trace");
    let rendered = trace.render();
    let components = trace.components();

    // ≥2 shard components: B's own enqueue on the survivor's shard,
    // plus the FrameDrained join on the owner's shard.
    let shards: Vec<&&str> = components.iter().filter(|c| c.starts_with("shard-")).collect();
    assert!(shards.len() >= 2, "trace must span >= 2 shards, got {shards:?}\n{rendered}");

    // ≥2 endpoints: the survivor's worker events plus the owner's
    // store-side spill, joined by ref key.
    let owner_s = e_owner.to_string();
    let survivor_s = e_survivor.to_string();
    assert!(
        components.iter().any(|c| c.contains(&survivor_s)),
        "trace must carry the survivor's events\n{rendered}"
    );
    assert!(
        components.iter().any(|c| c.contains(&owner_s)),
        "trace must carry the dead owner's events (spill join)\n{rendered}"
    );

    // The fabric's failover is visible and attributed to B, and the
    // anonymous spill/drain events joined in by ref key.
    let mut saw_failover = false;
    let mut saw_drain = false;
    let mut saw_spill = false;
    let mut saw_success = false;
    for e in &trace.events {
        match &e.kind {
            TraceKind::ReplicaFailover { key } => {
                saw_failover |= *key == key_a && e.component.starts_with("fabric-");
            }
            TraceKind::FrameDrained { key } => saw_drain |= *key == key_a,
            TraceKind::Spilled { key } => saw_spill |= *key == key_a,
            TraceKind::WorkerFinished { success, .. } => saw_success |= *success,
            _ => {}
        }
    }
    assert!(saw_failover, "trace must contain the fabric's ReplicaFailover\n{rendered}");
    assert!(saw_drain, "the decommission drain must join B's timeline by ref key\n{rendered}");
    assert!(saw_spill, "the owner-side spill must join B's timeline by ref key\n{rendered}");
    assert!(saw_success, "B's worker events must be present\n{rendered}");
    match &trace.terminal().expect("B's timeline must close").kind {
        TraceKind::ResultStored { state, .. } => assert_eq!(*state, "success"),
        other => panic!("B's terminal must be ResultStored, got {other:?}\n{rendered}"),
    }

    fh2.shutdown();
    h2.join();
}

/// The CI conservation invariant, proven from ONE metrics snapshot and
/// nothing else: `tasks_submitted == completed + failed + in_flight`.
/// When `FUNCX_METRICS_OUT` is set (the CI churn job), the snapshot's
/// JSON exposition is written there for upload as an artifact.
#[test]
fn snapshot_alone_proves_task_conservation() {
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alice");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("live", "").unwrap();
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
        .latency(svc.latency.clone())
        .clock(svc.clock.clone())
        .recorder(svc.recorder.clone())
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let f = fc.register_function("echo", Payload::Echo).unwrap();

    // 20 completed...
    let tasks: Vec<_> = (0..20i64).map(|i| fc.run(f, ep, &Value::Int(i)).unwrap()).collect();
    for t in &tasks {
        fc.get_result(*t, Duration::from_secs(15)).unwrap();
    }
    // ...plus 5 stranded in flight on an endpoint with no agent.
    let dark = fc.register_endpoint("dark", "").unwrap();
    for _ in 0..5 {
        fc.run(f, dark, &Value::Null).unwrap();
    }

    let snap = svc.metrics_snapshot();
    let submitted = snap.counter_total("funcx_tasks_submitted_total");
    let completed = snap.counter_total("funcx_tasks_completed_total");
    let failed = snap.counter_total("funcx_tasks_failed_total");
    let in_flight = snap.gauge_total("funcx_tasks_in_flight");
    assert_eq!(submitted, 25);
    assert!(in_flight >= 0, "in-flight gauge cannot go negative");
    assert_eq!(
        submitted,
        completed + failed + in_flight as u64,
        "conservation: submitted ({submitted}) != completed ({completed}) + \
         failed ({failed}) + in_flight ({in_flight})"
    );

    // Both exposition writers carry the invariant's inputs.
    let json = snap.to_json();
    let text = snap.to_text();
    let names =
        ["funcx_tasks_submitted_total", "funcx_tasks_completed_total", "funcx_tasks_in_flight"];
    for name in names {
        assert!(json.contains(name), "JSON exposition must list {name}");
        assert!(text.contains(name), "text exposition must list {name}");
    }
    if let Ok(path) = std::env::var("FUNCX_METRICS_OUT") {
        std::fs::write(&path, &json).expect("write metrics snapshot artifact");
    }

    // The SDK surfaces the same snapshot and the per-task trace.
    let client_snap = fc.metrics();
    assert_eq!(client_snap.counter_total("funcx_tasks_submitted_total"), submitted);
    let t = fc.trace(tasks[0]).expect("completed task must have a trace");
    assert!(t.terminal().is_some(), "completed task's timeline must close");

    fh.shutdown();
    agent.join();
}

/// 100k-task soak: the latency breakdown retains O(in-flight) records
/// (never the all-time task count) and the recorder's rings stay
/// bounded at capacity × components while counting their drops.
#[test]
fn latency_breakdown_and_recorder_are_bounded_under_soak() {
    let lb = LatencyBreakdown::new();
    let mut completed = 0u64;
    for i in 0..100_000u64 {
        let id = TaskId::new();
        let t = i as f64 * 1e-3;
        lb.on_submit(id, t);
        lb.on_queued(id, t + 1e-4);
        lb.on_forwarded(id, t + 2e-4);
        lb.on_started(id, t + 3e-4);
        lb.on_finished(id, t + 4e-4);
        // Only 1 in 10 completes: 90k stampings stay "in flight", far
        // beyond the per-stripe cap — eviction must bound the map.
        if i % 10 == 0 {
            assert!(lb.on_result_stored(id, t + 5e-4).is_some());
            completed += 1;
        }
    }
    assert_eq!(completed, 10_000);
    // 16 stripes × MAX_TRACKED_PER_STRIPE is the hard ceiling; the
    // all-time count (90k live stampings) must NOT be retained.
    assert!(
        lb.in_flight() <= 16 * MAX_TRACKED_PER_STRIPE,
        "latency map must stay bounded, holds {}",
        lb.in_flight()
    );
    // The folded histograms still summarize every completed task.
    let s = lb.stage_summaries();
    assert_eq!(s.completed, 10_000);
    assert!(s.total.p99 > 0.0 && s.total.count == 10_000);

    // Recorder rings: 100k events over 4 components at capacity 512.
    let rec = FlightRecorder::with_capacity(512);
    for i in 0..100_000u32 {
        let id = TaskId::new();
        rec.record(
            &format!("shard-{}", i % 4),
            None,
            Some(id),
            f64::from(i),
            TraceKind::Redispatched { attempt: i },
        );
    }
    assert!(rec.resident() <= 4 * 512, "rings must stay bounded, hold {}", rec.resident());
    assert_eq!(rec.dropped(), 100_000 - rec.resident() as u64, "drops must be accounted");
}
