//! End-to-end pins for the data fabric (§5): pass-by-reference dispatch
//! through the live stack, tier spill/reload byte-identity, the
//! cross-endpoint fetch ladder, and clean failure (`Error::NotFound`,
//! never a panic) when a ref's frame has been evicted by TTL.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::ids::EndpointId;
use funcx::common::task::Payload;
use funcx::common::time::WallClock;
use funcx::datastore::{checksum, DataFabric, FetchPlan, Tier, TieredConfig, TieredStore};
use funcx::endpoint::{link, EndpointBuilder};
use funcx::metrics::Counters;
use funcx::routing::LocalityAware;
use funcx::serialize::{pack, Value};
use funcx::service::FuncXService;
use funcx::transfer::TransferService;

/// Seed for CI's churn kill-matrix: perturbs payload sizes so each
/// matrix leg drives the same kill sequence through different frame
/// shapes. Defaults to 0 under plain `cargo test`.
fn chaos_seed() -> usize {
    std::env::var("FUNCX_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// Tier round-trip acceptance pin: a frame that spills to the disk tier
/// reloads byte-identical (same checksum, same packed-frame bytes), and
/// a memory-tier hit is pointer-identical to the stored frame — zero
/// decode/re-encode on either fetch path.
#[test]
fn spilled_frames_round_trip_byte_identical() {
    let store = TieredStore::new(
        EndpointId::new(),
        TieredConfig { mem_high_watermark: 96 * 1024, default_ttl_s: 0.0, spool_dir: None },
    )
    .unwrap();
    let a = pack(&Value::Bytes(vec![0xA1; 64 * 1024]), 0).unwrap();
    let b = pack(&Value::Bytes(vec![0xB2; 64 * 1024]), 0).unwrap();
    let ra = store.put("a", a.clone(), 0.0).unwrap();
    store.put("b", b.clone(), 0.0).unwrap();

    // The watermark fits one frame: the background spiller moves the
    // older key to disk.
    assert!(store.settle(Duration::from_secs(10)), "spill must complete");
    assert_eq!(store.tier_of("a"), Some(Tier::Disk));
    assert_eq!(store.tier_of("b"), Some(Tier::Memory));
    assert!(store.stats.spills.load(Relaxed) >= 1);

    // Memory-tier get: the SAME allocation (pointer pin).
    let got_b = store.get("b", 0.0).unwrap();
    assert!(got_b.same_allocation(&b), "memory tier must hand back the stored frame");

    // Disk-tier get: byte-identical reload of the raw wire bytes.
    let got_a = store.get("a", 0.0).unwrap();
    assert_eq!(got_a.as_slice(), a.as_slice(), "spill/reload must be byte-identical");
    assert_eq!(checksum(got_a.as_slice()), ra.checksum);
    // Still the original packed frame: unpacking yields the original
    // value without any re-encode having happened in between.
    assert_eq!(
        funcx::serialize::unpack(&got_a).unwrap(),
        Value::Bytes(vec![0xA1; 64 * 1024])
    );
}

/// The full pass-by-reference lifecycle through the live stack: an
/// input above the service cap is offloaded at submit, the task crosses
/// the queues as a compact ref, and the worker resolves the frame from
/// the service store through the endpoint's fabric.
#[test]
fn large_payload_dispatches_by_reference_end_to_end() {
    let clock = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 4096, // force by-ref for a 64 KB input
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
    let e = svc.register_endpoint(&tok, "laptop", "").unwrap();

    // Endpoint-side fabric. No manual peering: the forwarder advertises
    // the service store down the link (and the agent advertises this
    // store upstream), so both fabrics auto-peer on connect.
    let local = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
    let fabric = Arc::new(DataFabric::new(local));

    let (fwd, agent_side) = link();
    let handle = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 2, ..Default::default() })
        .fabric(fabric.clone())
        .clock(clock)
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(e, fwd).unwrap();

    let input = Value::Bytes(vec![0x5A; 64 * 1024]);
    let r = svc.submit(&tok, f, e, &input).unwrap();
    let out = svc.wait_result(r.task, Duration::from_secs(10)).unwrap();
    assert_eq!(out, input, "by-ref echo returns the original payload");

    assert_eq!(Counters::get(&svc.counters.tasks_ref_dispatched), 1);
    assert!(Counters::get(&svc.counters.bytes_offloaded) >= 64 * 1024);
    assert_eq!(fh.stats.ref_dispatched.load(Relaxed), 1);
    assert!(
        fabric.stats.frames_forwarded.load(Relaxed) + fabric.stats.cache_hits.load(Relaxed)
            >= 1,
        "the worker resolved the frame through the fabric"
    );

    fh.shutdown();
    handle.join();
}

/// THE closed-loop acceptance pin (result offload + ref forwarding +
/// locality routing): a 3-task chain — A's large output becomes B's
/// input becomes C's input — completes with the intermediate bytes
/// never transiting the service queues inline, B and C routed to the
/// data owner's managers by `LocalityAware`, and their input resolves
/// served from the endpoint's own store.
#[test]
fn three_task_chain_forwards_refs_and_routes_to_the_data() {
    let clock = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 4096, // force A's input by-ref too
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
    let e = svc.register_endpoint(&tok, "cluster", "").unwrap();

    // Endpoint fabric. Peering happens automatically in both directions
    // on connect (§5 peer auto-discovery): the endpoint resolves
    // service-owned input refs, the service resolves endpoint-owned
    // result refs — no manual connect_peer wiring.
    let local = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
    let fabric = Arc::new(DataFabric::new(local.clone()));

    let scheduler = LocalityAware::new(0);
    let route_stats = scheduler.stats.clone();

    let (fwd, agent_side) = link();
    let handle = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 2,
            workers_per_node: 2,
            max_result_bytes: 4096, // force outputs by-ref
            ..Default::default()
        })
        .fabric(fabric.clone())
        .scheduler(Box::new(scheduler))
        .clock(clock)
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(e, fwd).unwrap();

    // A: 256 KB input (offloaded at submit into the service store);
    // echo produces a 256 KB output, offloaded into the ENDPOINT store.
    let payload = Value::Bytes(vec![0x42; 256 * 1024]);
    let a = svc.submit(&tok, f, e, &payload).unwrap();
    let ref_a = svc.wait_result_ref(a.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_a.owner, e, "A's result lives in the endpoint's store");

    // B and C: submitted by ref — the service brokers a ~100-byte ref
    // and never touches the intermediate bytes.
    let b = svc.submit_by_ref(&tok, f, e, &ref_a).unwrap();
    let ref_b = svc.wait_result_ref(b.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_b.owner, e);
    let c = svc.submit_by_ref(&tok, f, e, &ref_b).unwrap();
    let out = svc.wait_result(c.task, Duration::from_secs(10)).unwrap();
    assert_eq!(out, payload, "the chain round-trips the payload bit-for-bit");

    // Byte pins: nothing big crossed the service queues in either
    // direction — all three inputs and all three outputs were refs.
    assert_eq!(Counters::get(&svc.counters.bytes_through_service), 0);
    assert_eq!(Counters::get(&svc.counters.result_bytes_through_service), 0);
    assert_eq!(Counters::get(&svc.counters.results_ref_offloaded), 3);
    assert_eq!(Counters::get(&svc.counters.tasks_ref_forwarded), 2);
    assert_eq!(fh.stats.ref_results.load(Relaxed), 3);

    // Locality pins: B and C were hinted with the endpoint as data
    // owner and routed to its managers (A's hint named the service
    // store — no manager lives there, so it counts remote)...
    assert_eq!(route_stats.local_routes.load(Relaxed), 2, "B and C routed to the data");
    assert_eq!(route_stats.remote_routes.load(Relaxed), 1, "A's input is service-owned");
    // ...and their resolves were local store hits: the bytes never left
    // the endpoint between stages.
    assert!(
        fabric.stats.local_hits.load(Relaxed) >= 2,
        "B's and C's inputs must resolve from the endpoint's own store, got {}",
        fabric.stats.local_hits.load(Relaxed)
    );

    // Result-frame GC: every intermediate was reclaimed the moment it
    // was consumed — A's and B's outputs when their chain successors
    // completed, C's on retrieval — so nothing lingers until TTL.
    assert_eq!(Counters::get(&svc.counters.result_frames_reclaimed), 3);
    assert!(
        local.is_empty(),
        "endpoint store must hold no task-result frames after the chain, has {}",
        local.len()
    );
    assert!(
        svc.fabric.local().is_empty(),
        "service store must hold no offloaded inputs after the chain"
    );

    fh.shutdown();
    handle.join();
}

/// THE cross-shard acceptance pin for the sharded service plane: the
/// A→B→C ref chain through a FOUR-shard service, with the data-owner
/// endpoint and the consumer endpoint deliberately hashing to
/// *different* shards. A runs on the owner; B and C run on the
/// consumer with their inputs passed by ref. Every hop crosses shard
/// boundaries — the offloaded frames live behind one shard's fabric
/// while the consuming tasks' state lives behind another — and still
/// not one payload byte transits the service inline, because shard
/// fabrics are full-mesh peered and every endpoint store is wired into
/// every shard on advertisement.
#[test]
fn cross_shard_chain_moves_zero_payload_bytes_through_the_service() {
    let clock = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 4096,
        service_shards: 4,
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();

    // Endpoint ids are random, so draw consumers until one lands on a
    // different shard than the owner (P(miss) = 1/4 per draw).
    let map = svc.shard_map();
    let e_owner = svc.register_endpoint(&tok, "owner", "").unwrap();
    let mut e_consumer = svc.register_endpoint(&tok, "consumer", "").unwrap();
    let mut draws = 0;
    while map.shard_for_endpoint(e_consumer) == map.shard_for_endpoint(e_owner) {
        draws += 1;
        assert!(draws < 256, "could not draw a distinct shard in 256 tries");
        e_consumer = svc.register_endpoint(&tok, &format!("consumer{draws}"), "").unwrap();
    }
    assert_ne!(
        map.shard_for_endpoint(e_owner),
        map.shard_for_endpoint(e_consumer),
        "the chain must cross shards"
    );

    // Owner stack: A executes here; its oversized result is offloaded
    // into this endpoint's store.
    let store_owner = Arc::new(TieredStore::new(e_owner, TieredConfig::default()).unwrap());
    let (fwd1, agent1) = link();
    let h1 = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 2,
            max_result_bytes: 4096,
            ..Default::default()
        })
        .fabric(Arc::new(DataFabric::new(store_owner.clone())))
        .clock(clock.clone())
        .heartbeat_period(0.05)
        .start(agent1);
    let fh1 = svc.connect_endpoint(e_owner, fwd1).unwrap();

    // Consumer stack: B and C execute here, resolving their by-ref
    // inputs straight from the owner's store (endpoint-to-endpoint
    // peering, like the fetch ladder) — off the service's inline path.
    let store_consumer =
        Arc::new(TieredStore::new(e_consumer, TieredConfig::default()).unwrap());
    let fabric_consumer = Arc::new(DataFabric::new(store_consumer.clone()));
    // No hand-wired peer mesh: the consumer discovers the owner's store
    // lazily from the registry on its first fabric miss (ROADMAP item:
    // endpoint-to-endpoint peering without manual connect_peer calls).
    fabric_consumer.with_registry(svc.registry.clone());
    let (fwd2, agent2) = link();
    let h2 = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 2,
            max_result_bytes: 4096,
            ..Default::default()
        })
        .fabric(fabric_consumer.clone())
        .clock(clock)
        .heartbeat_period(0.05)
        .start(agent2);
    let fh2 = svc.connect_endpoint(e_consumer, fwd2).unwrap();

    // A on the owner: 256 KB in (offloaded at submit), 256 KB out
    // (offloaded into the owner's store).
    let payload = Value::Bytes(vec![0x42; 256 * 1024]);
    let a = svc.submit(&tok, f, e_owner, &payload).unwrap();
    let ref_a = svc.wait_result_ref(a.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_a.owner, e_owner, "A's result lives in the owner's store");

    // B and C on the consumer, chained by ref across the shard split.
    let b = svc.submit_by_ref(&tok, f, e_consumer, &ref_a).unwrap();
    let ref_b = svc.wait_result_ref(b.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_b.owner, e_consumer, "B's result lives in the consumer's store");
    let c = svc.submit_by_ref(&tok, f, e_consumer, &ref_b).unwrap();
    let out = svc.wait_result(c.task, Duration::from_secs(10)).unwrap();
    assert_eq!(out, payload, "the chain round-trips the payload across shards");

    // Byte pins: zero inline payload bytes through the service in
    // either direction, exactly as in the single-shard chain.
    assert_eq!(Counters::get(&svc.counters.bytes_through_service), 0);
    assert_eq!(Counters::get(&svc.counters.result_bytes_through_service), 0);
    assert_eq!(Counters::get(&svc.counters.results_ref_offloaded), 3);
    assert_eq!(Counters::get(&svc.counters.tasks_ref_forwarded), 2);

    // The cross-endpoint hop happened endpoint-side: B's input was
    // forwarded from the owner's store into the consumer's fabric, and
    // C's input (B's own output) was a local hit.
    assert!(
        fabric_consumer.stats.frames_forwarded.load(Relaxed)
            + fabric_consumer.stats.cache_hits.load(Relaxed)
            >= 1,
        "B's input must resolve through the consumer's fabric, not the service"
    );
    assert!(
        fabric_consumer.stats.local_hits.load(Relaxed) >= 1,
        "C's input must be a local hit in the consumer's store"
    );
    assert!(
        fabric_consumer.stats.lazy_peers.load(Relaxed) >= 1,
        "the owner's store was discovered lazily through the registry"
    );

    // Eager result GC still closes the loop across shards: A's and B's
    // outputs reclaimed when their consumers completed, C's on
    // retrieval.
    assert_eq!(Counters::get(&svc.counters.result_frames_reclaimed), 3);

    fh1.shutdown();
    h1.join();
    fh2.shutdown();
    h2.join();
}

/// THE churn acceptance pin (§4.1 + §5 survivability): the ref-owner
/// endpoint is killed mid A→B→C chain with replication enabled. The
/// chain still completes — B's input fails over to the replica copy the
/// service pushed to the surviving endpoint when A's result was stored
/// — with zero payload bytes ever transiting the service inline, and
/// the failover observable in the shared counters.
#[test]
fn chain_survives_ref_owner_death_via_replica() {
    let clock = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 4096,
        replication_factor: 2,
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
    let e1 = svc.register_endpoint(&tok, "doomed", "").unwrap();
    let e2 = svc.register_endpoint(&tok, "survivor", "").unwrap();

    // The doomed ref owner.
    let store1 = Arc::new(TieredStore::new(e1, TieredConfig::default()).unwrap());
    let (fwd1, agent1) = link();
    let h1 = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 1,
            max_result_bytes: 4096, // force results by-ref
            ..Default::default()
        })
        .fabric(Arc::new(DataFabric::new(store1.clone())))
        .clock(clock.clone())
        .heartbeat_period(0.05)
        .start(agent1);
    let fh1 = svc.connect_endpoint(e1, fwd1).unwrap();

    // The survivor, sharing the service's metrics sink so its failover
    // resolutions land in the same counters a deployment would scrape.
    let store2 = Arc::new(TieredStore::new(e2, TieredConfig::default()).unwrap());
    let fabric2 = Arc::new(DataFabric::new(store2.clone()));
    fabric2.with_counters(svc.counters.clone());
    let scheduler = LocalityAware::new(0);
    let route_stats = scheduler.stats.clone();
    let (fwd2, agent2) = link();
    let h2 = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 2,
            max_result_bytes: 4096,
            ..Default::default()
        })
        .fabric(fabric2.clone())
        .scheduler(Box::new(scheduler))
        .clock(clock)
        .heartbeat_period(0.05)
        .start(agent2);
    let fh2 = svc.connect_endpoint(e2, fwd2).unwrap();

    // Replication needs the survivor's store advertised before A's
    // result lands.
    let t0 = std::time::Instant::now();
    while svc.registry.advertised_store(e1).is_none()
        || svc.registry.advertised_store(e2).is_none()
    {
        assert!(t0.elapsed() < Duration::from_secs(5), "advertisements must arrive");
        std::thread::yield_now();
    }

    // A on the doomed endpoint: its ~256 KB result is offloaded into
    // store1 and replicated to the survivor at store-result time. The
    // size is perturbed by the kill-matrix seed so each CI leg pushes a
    // different frame shape through the replication/failover path.
    let payload = Value::Bytes(vec![0x42; 256 * 1024 + (chaos_seed() % 16) * 1024]);
    let a = svc.submit(&tok, f, e1, &payload).unwrap();
    let ref_a = svc.wait_result_ref(a.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_a.owner, e1);
    assert_eq!(ref_a.replicas, vec![e2], "the replica set rides on the stored ref");
    assert_eq!(Counters::get(&svc.counters.replicas_created), 1);

    // Kill the ref owner mid-chain: agent gone, its frames dead with
    // the host, its address unreachable, the registry told. Only the
    // survivor's replica holds A's output now.
    fh1.shutdown();
    h1.join();
    store1.purge_all();
    svc.fabric.disconnect_peer(e1);
    svc.registry.withdraw_store(e1);

    // B and C on the survivor, chained by ref. B's input resolve must
    // fail over to the replica copy sitting in its own store.
    let b = svc.submit_by_ref(&tok, f, e2, &ref_a).unwrap();
    let ref_b = svc.wait_result_ref(b.task, Duration::from_secs(10)).unwrap();
    assert_eq!(ref_b.owner, e2);
    let c = svc.submit_by_ref(&tok, f, e2, &ref_b).unwrap();
    let out = svc.wait_result(c.task, Duration::from_secs(10)).unwrap();
    assert_eq!(out, payload, "the chain round-trips the payload through the owner's death");

    // Failover pins: B resolved A's output from the replica...
    assert!(fabric2.stats.failovers.load(Relaxed) >= 1, "B's input must fail over");
    assert!(Counters::get(&svc.counters.failover_resolutions) >= 1);
    // ...and not one payload byte crossed the service inline, in either
    // direction (replica pushes ride the fabric, off the inline path).
    assert_eq!(Counters::get(&svc.counters.bytes_through_service), 0);
    assert_eq!(Counters::get(&svc.counters.result_bytes_through_service), 0);
    // Replica-aware locality: B (hinted at a replica holder) and C
    // (hinted at the owner) both routed to the survivor's managers.
    assert_eq!(route_stats.local_routes.load(Relaxed), 2);
    assert_eq!(route_stats.remote_routes.load(Relaxed), 0);

    fh2.shutdown();
    h2.join();
}

/// Satellite pin: a ref whose frame was evicted from the store (here
/// deterministically removed; TTL expiry takes the same `NotFound`
/// path, unit-pinned in `datastore::tiered`) fails the task with a
/// clean `not found` error at the worker on dispatch — no panic,
/// terminal Failed state, message surfaced to `get_result`.
#[test]
fn evicted_ref_fails_cleanly_on_dispatch() {
    let clock = Arc::new(WallClock::new());
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 1024,
        ..Default::default()
    })
    .with_clock(clock.clone());
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
    let e = svc.register_endpoint(&tok, "laptop", "").unwrap();

    // Submit while no agent is connected: the by-ref task waits in the
    // queue; meanwhile its frame is evicted from the service store.
    let input = Value::Bytes(vec![0x77; 16 * 1024]);
    let r = svc.submit(&tok, f, e, &input).unwrap();
    assert!(
        svc.fabric.local().remove(&format!("task-input:{}", r.task)).unwrap(),
        "the offloaded input frame is keyed by task id"
    );

    let local = Arc::new(TieredStore::new(e, TieredConfig::default()).unwrap());
    let fabric = Arc::new(DataFabric::new(local));
    // No manual peering: the forwarder's downstream advertisement wires
    // the service store into this fabric on connect.
    let (fwd, agent_side) = link();
    let handle = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
        .fabric(fabric)
        .clock(clock)
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(e, fwd).unwrap();

    match svc.wait_result(r.task, Duration::from_secs(10)) {
        Err(funcx::Error::TaskFailed(msg)) => {
            assert!(msg.contains("not found"), "expected a NotFound failure, got: {msg}");
        }
        other => panic!("evicted ref must fail the task cleanly, got {other:?}"),
    }

    fh.shutdown();
    handle.join();
}

/// An endpoint with no fabric attached fails by-ref tasks cleanly too
/// (the capability is opt-in, like the data channel and the runtime).
#[test]
fn missing_fabric_fails_ref_tasks_cleanly() {
    let svc = FuncXService::new(ServiceConfig {
        max_payload_bytes: 1024,
        ..Default::default()
    });
    let (_u, tok) = svc.bootstrap_user("alice");
    let f = svc.register_function(&tok, "echo", Payload::Echo, None).unwrap();
    let e = svc.register_endpoint(&tok, "laptop", "").unwrap();
    let (fwd, agent_side) = link();
    let handle = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(e, fwd).unwrap();

    let r = svc.submit(&tok, f, e, &Value::Bytes(vec![1; 8192])).unwrap();
    match svc.wait_result(r.task, Duration::from_secs(10)) {
        Err(funcx::Error::TaskFailed(msg)) => {
            assert!(msg.contains("no data fabric"), "got: {msg}");
        }
        other => panic!("unexpected {other:?}"),
    }
    fh.shutdown();
    handle.join();
}

/// The cross-endpoint fetch ladder: direct raw-frame forwarding below
/// the wide-area threshold, the Globus transfer model at/above it.
#[test]
fn fetch_ladder_forwards_frames_and_falls_back_to_globus() {
    let owner_a = EndpointId::new();
    let owner_b = EndpointId::new();
    let sa = Arc::new(TieredStore::new(owner_a, TieredConfig::default()).unwrap());
    let sb = Arc::new(TieredStore::new(owner_b, TieredConfig::default()).unwrap());
    let fab = DataFabric::new(sb);
    fab.connect_peer(owner_a, sa.clone());
    let ts = TransferService::new();
    let ga = ts.register_endpoint("a#dtn", 1.25e9, 2.0);
    let gb = ts.register_endpoint("b#dtn", 1.25e9, 2.0);
    fab.with_wide_area(ts.clone(), 1024 * 1024);
    fab.map_storage(owner_a, ga);
    fab.map_storage(owner_b, gb);

    // Small frame: endpoint-to-endpoint forward of the raw wire bytes.
    let small = pack(&Value::Bytes(vec![1; 512]), 0).unwrap();
    let r_small = sa.put("small", small.clone(), 0.0).unwrap();
    assert_eq!(fab.plan(&r_small, 0.0), FetchPlan::PeerForward);
    let got = fab.resolve(&r_small, 0.0).unwrap();
    assert!(got.same_allocation(&small), "in-process forward shares the frame allocation");
    assert_eq!(fab.stats.frames_forwarded.load(Relaxed), 1);
    // Re-resolving hits the local cache and counts the hit.
    fab.resolve(&r_small, 0.0).unwrap();
    assert_eq!(fab.stats.cache_hits.load(Relaxed), 1);
    assert_eq!(fab.cache_hits_of(&r_small), 1);

    // GlobusFile-sized frame: the ladder routes it through the modeled
    // wide-area transfer (setup + wire time on the 10 Gb/s pair).
    let big = pack(&Value::Bytes(vec![2; 2 * 1024 * 1024]), 0).unwrap();
    let r_big = sa.put("big", big.clone(), 0.0).unwrap();
    match fab.plan(&r_big, 0.0) {
        FetchPlan::Globus { est_s } => assert!(est_s > 2.0, "estimate {est_s}"),
        other => panic!("expected Globus plan, got {other:?}"),
    }
    let got = fab.resolve(&r_big, 0.0).unwrap();
    assert_eq!(got.as_slice(), big.as_slice());
    assert_eq!(fab.stats.globus_transfers.load(Relaxed), 1);
    assert!(ts.in_flight_bytes(ga, gb, 0.5) >= 2 * 1024 * 1024);
}
