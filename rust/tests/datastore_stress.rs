//! Concurrency stress over the tiered store's per-key state machine:
//! reader threads hammer `get`/`resolve` on live keys while a
//! watermark-crossing put storm forces continuous background spills and
//! an overwrite churn keeps abandoning in-flight transitions.
//!
//! The pinned invariants (the tentpole's correctness half):
//! * a *live* key NEVER resolves `NotFound`/`Corrupt`, no matter which
//!   transition (`Spilling`, `OnDisk`, `Promoting`) it is caught in;
//! * no frame is lost mid-transition — after the storm settles, every
//!   ref minted during it still resolves byte-identical;
//! * the spiller actually ran (the storm crossed the watermark), so the
//!   reads above genuinely raced spills.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use funcx::common::ids::EndpointId;
use funcx::datastore::{DataRef, Tier, TieredConfig, TieredStore};
use funcx::serialize::Buffer;

fn frame(byte: u8, len: usize) -> Buffer {
    Buffer::from_vec(vec![byte; len])
}

#[test]
fn memory_hits_survive_a_spill_storm() {
    const WATERMARK: usize = 256 * 1024;
    const HOT_KEYS: usize = 8;
    const STORM_PUTS: usize = 300; // ~10 MB through a 256 KB memory tier
    const CHURN_KEYS: usize = 4;
    const CHURN_ROUNDS: usize = 200;

    let s = Arc::new(
        TieredStore::new(
            EndpointId::new(),
            TieredConfig {
                mem_high_watermark: WATERMARK,
                default_ttl_s: 0.0,
                spool_dir: None,
            },
        )
        .unwrap(),
    );

    // Hot set: small frames the readers touch constantly. They stay
    // live for the whole run, so any NotFound/Corrupt on them is a
    // state-machine bug, not test noise.
    let hot: Vec<(String, Buffer, DataRef)> = (0..HOT_KEYS)
        .map(|i| {
            let key = format!("hot{i}");
            let f = frame(0xA0 + i as u8, 1024);
            let r = s.put(&key, f.clone(), 0.0).unwrap();
            (key, f, r)
        })
        .collect();

    let stop = Arc::new(AtomicBool::new(false));

    // Reader threads: get + resolve every hot key in a tight loop.
    // resolve() verifies size + checksum, so a frame served from the
    // wrong generation or a torn transition would surface as Corrupt.
    let readers: Vec<_> = (0..4)
        .map(|_| {
            let s = s.clone();
            let stop = stop.clone();
            let hot = hot.clone();
            std::thread::spawn(move || {
                let mut rounds = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    for (key, f, r) in &hot {
                        let got = s
                            .get(key, 0.0)
                            .unwrap_or_else(|e| panic!("live hot key {key}: {e}"));
                        assert_eq!(got.len(), f.len(), "wrong frame length for {key}");
                        let via_ref = s
                            .resolve(r, 0.0)
                            .unwrap_or_else(|e| panic!("live ref {key}: {e}"));
                        assert_eq!(via_ref.as_slice()[0], f.as_slice()[0]);
                    }
                    rounds += 1;
                }
                rounds
            })
        })
        .collect();

    // The storm: unique 32 KB puts, each crossing the watermark, so the
    // background spiller runs continuously under the readers.
    let storm = {
        let s = s.clone();
        std::thread::spawn(move || {
            let mut refs = Vec::with_capacity(STORM_PUTS);
            for i in 0..STORM_PUTS {
                let f = frame((i % 251) as u8, 32 * 1024);
                let r = s.put(&format!("storm{i}"), f, 0.0).unwrap();
                // Re-read an earlier storm ref mid-storm: it may be
                // Resident, Spilling, OnDisk, or Promoting right now —
                // all must serve verified bytes.
                if i >= 8 {
                    let back: &DataRef = &refs[i / 2];
                    let got = s
                        .resolve(back, 0.0)
                        .unwrap_or_else(|e| panic!("live storm ref {}: {e}", back.key));
                    assert_eq!(got.len() as u64, back.size);
                }
                refs.push(r);
            }
            refs
        })
    };

    // Overwrite churn: rewrites a small key set while the spiller may
    // hold their old generations mid-spill — exercising the
    // gen-mismatch abandon paths. The fresh ref must resolve until the
    // same thread overwrites it again.
    let churn = {
        let s = s.clone();
        std::thread::spawn(move || {
            let mut last = Vec::new();
            for round in 0..CHURN_ROUNDS {
                last.clear();
                for k in 0..CHURN_KEYS {
                    let f = frame((round + k) as u8, 16 * 1024);
                    let r = s.put(&format!("churn{k}"), f, 0.0).unwrap();
                    last.push(r);
                }
                for r in &last {
                    let got = s
                        .resolve(r, 0.0)
                        .unwrap_or_else(|e| panic!("fresh churn ref {}: {e}", r.key));
                    assert_eq!(got.len() as u64, r.size);
                }
            }
            last
        })
    };

    let storm_refs = storm.join().expect("storm thread");
    let churn_refs = churn.join().expect("churn thread");
    stop.store(true, Ordering::Relaxed);
    let rounds: u64 = readers.into_iter().map(|h| h.join().expect("reader thread")).sum();
    assert!(rounds > 0, "readers must have raced the storm");

    // Quiesce, then audit: nothing was lost mid-transition.
    assert!(s.settle(Duration::from_secs(30)), "store must settle after the storm");
    assert!(
        s.stats.spills.load(Ordering::Relaxed) > 0,
        "the storm never forced a spill — the stress raced nothing"
    );
    assert!(s.mem_bytes() <= WATERMARK, "watermark restored after settle");
    assert_eq!(
        s.len(),
        HOT_KEYS + STORM_PUTS + CHURN_KEYS,
        "every live key survives the storm"
    );
    for r in storm_refs.iter().chain(churn_refs.iter()) {
        let got = s.resolve(r, 0.0).unwrap_or_else(|e| panic!("settled ref {}: {e}", r.key));
        assert_eq!(got.len() as u64, r.size, "byte-identical after settle: {}", r.key);
    }
    for (key, f, _) in &hot {
        let got = s.get(key, 0.0).unwrap();
        assert_eq!(got.as_slice(), f.as_slice(), "hot key intact: {key}");
    }
    // The constantly-touched hot set should have been protected by LRU:
    // at least one storm key is on disk while the store holds the hot
    // frames' bytes in some tier — tier placement is best-effort, but
    // the spilled set must come from the storm.
    assert!(
        (0..STORM_PUTS).any(|i| s.tier_of(&format!("storm{i}")) == Some(Tier::Disk)),
        "spilled victims must include storm keys"
    );
}
