//! Allocation-count pins for the serialization facade (acceptance
//! criterion: pack reuses its scratch — one exact-size allocation per
//! frame — and cloning a packed buffer allocates nothing).
//!
//! A counting global allocator wraps the system one; everything runs in
//! ONE test function so no sibling test's allocations pollute the
//! deltas (each integration-test file is its own binary).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use funcx::serialize::{pack, unpack, Buffer, Value};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOCS.load(Ordering::SeqCst);
    let r = f();
    (ALLOCS.load(Ordering::SeqCst) - before, r)
}

#[test]
fn facade_allocation_discipline() {
    const N: usize = 100;

    // Warm up: thread-local scratch, cached empty frame, any lazy
    // formatting tables.
    let bytes_val = Value::Bytes(vec![0xA5; 4096]);
    let json_val = Value::map([
        ("inputs", Value::Str("image_000.h5".into())),
        ("meta", Value::List(vec![Value::Int(1), Value::Bool(true), Value::Float(2.5)])),
    ]);
    let binc_val = Value::F32s(vec![1.5; 1024]);
    for v in [&bytes_val, &json_val, &binc_val] {
        let _ = pack(v, 7).unwrap();
    }
    let _ = Buffer::empty();

    // Pack = one exact-size allocation per frame, for every codec path
    // (Raw, Json, Binc): the scratch is reused, codecs append into it,
    // nothing else allocates. Slack covers a possible one-off scratch
    // regrow.
    for (name, v) in [("raw", &bytes_val), ("json", &json_val), ("binc", &binc_val)] {
        let (n, frames) = allocs_during(|| {
            (0..N).map(|_| pack(v, 7).unwrap()).collect::<Vec<_>>()
        });
        // N frame allocations + 1 for the collecting Vec (+ small slack
        // for its growth doublings).
        assert!(
            n <= N + 12,
            "{name}: {n} allocations for {N} packs — scratch reuse broken"
        );
        drop(frames);
    }

    // Cloning a packed buffer is a refcount bump: ZERO allocations.
    let frame = pack(&bytes_val, 7).unwrap();
    let (n, clones) = allocs_during(|| {
        let mut clones = Vec::with_capacity(1000);
        for _ in 0..1000 {
            clones.push(frame.clone());
        }
        clones
    });
    assert_eq!(n, 0, "cloning a packed buffer must not allocate");
    assert!(clones.iter().all(|c| c.same_allocation(&frame)));
    drop(clones);

    // The cached empty frame: zero allocations per call.
    let (n, _) = allocs_during(|| {
        for _ in 0..1000 {
            std::hint::black_box(Buffer::empty());
        }
    });
    assert_eq!(n, 0, "Buffer::empty must serve the cached frame");

    // Unpack decodes the body borrowed in place: the only allocations
    // are the ones the decoded Value itself needs (here: the Bytes vec),
    // not a copy of the frame first.
    let (n, _) = allocs_during(|| {
        for _ in 0..N {
            std::hint::black_box(unpack(&frame).unwrap());
        }
    });
    assert!(
        n <= 2 * N,
        "unpack allocated {n} times for {N} raw-bytes frames — body is being copied"
    );
}
