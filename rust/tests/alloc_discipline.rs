//! Allocation-count pins for the serialization facade (acceptance
//! criterion: pack reuses its scratch — one exact-size allocation per
//! frame — and cloning a packed buffer allocates nothing).
//!
//! A counting global allocator wraps the system one; everything runs in
//! ONE test function so no sibling test's allocations pollute the
//! deltas (each integration-test file is its own binary).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use funcx::common::ids::EndpointId;
use funcx::datastore::{TieredConfig, TieredStore};
use funcx::serialize::{pack, unpack, Buffer, Value};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOCS.load(Ordering::SeqCst);
    let r = f();
    (ALLOCS.load(Ordering::SeqCst) - before, r)
}

#[test]
fn facade_allocation_discipline() {
    const N: usize = 100;

    // Warm up: thread-local scratch, cached empty frame, any lazy
    // formatting tables.
    let bytes_val = Value::Bytes(vec![0xA5; 4096]);
    let json_val = Value::map([
        ("inputs", Value::Str("image_000.h5".into())),
        ("meta", Value::List(vec![Value::Int(1), Value::Bool(true), Value::Float(2.5)])),
    ]);
    let binc_val = Value::F32s(vec![1.5; 1024]);
    for v in [&bytes_val, &json_val, &binc_val] {
        let _ = pack(v, 7).unwrap();
    }
    let _ = Buffer::empty();

    // Pack = one exact-size allocation per frame, for every codec path
    // (Raw, Json, Binc): the scratch is reused, codecs append into it,
    // nothing else allocates. Slack covers a possible one-off scratch
    // regrow.
    for (name, v) in [("raw", &bytes_val), ("json", &json_val), ("binc", &binc_val)] {
        let (n, frames) = allocs_during(|| {
            (0..N).map(|_| pack(v, 7).unwrap()).collect::<Vec<_>>()
        });
        // N frame allocations + 1 for the collecting Vec (+ small slack
        // for its growth doublings).
        assert!(
            n <= N + 12,
            "{name}: {n} allocations for {N} packs — scratch reuse broken"
        );
        drop(frames);
    }

    // Cloning a packed buffer is a refcount bump: ZERO allocations.
    let frame = pack(&bytes_val, 7).unwrap();
    let (n, clones) = allocs_during(|| {
        let mut clones = Vec::with_capacity(1000);
        for _ in 0..1000 {
            clones.push(frame.clone());
        }
        clones
    });
    assert_eq!(n, 0, "cloning a packed buffer must not allocate");
    assert!(clones.iter().all(|c| c.same_allocation(&frame)));
    drop(clones);

    // The cached empty frame: zero allocations per call.
    let (n, _) = allocs_during(|| {
        for _ in 0..1000 {
            std::hint::black_box(Buffer::empty());
        }
    });
    assert_eq!(n, 0, "Buffer::empty must serve the cached frame");

    // Unpack of a Raw frame is ALLOCATION-FREE: it yields a
    // `Value::Blob` view borrowing the frame's allocation — the worker
    // reads a raw payload end to end without materialising an owned
    // vec (the zero-copy `Value` bytes pin).
    let (n, blobs) = allocs_during(|| {
        (0..N).map(|_| unpack(&frame).unwrap()).collect::<Vec<_>>()
    });
    assert!(
        n <= 1, // the collecting Vec only
        "{n} allocations for {N} raw unpacks — Blob view broken"
    );
    for v in &blobs {
        match v {
            Value::Blob(b) => assert!(
                b.same_allocation(&frame),
                "Blob must borrow the frame allocation"
            ),
            other => panic!("raw unpack must yield Blob, got {other:?}"),
        }
    }
    drop(blobs);

    // Non-raw frames still decode with only the Value's own
    // allocations, never a copy of the frame first.
    let json_frame = pack(&json_val, 7).unwrap();
    let (n, _) = allocs_during(|| {
        for _ in 0..N {
            std::hint::black_box(unpack(&json_frame).unwrap());
        }
    });
    assert!(
        n <= 64 * N,
        "unpack allocated {n} times for {N} json frames — body is being copied"
    );

    // The tiered data store's fetch paths: a memory-tier get is a
    // refcount bump (ZERO allocations beyond the key lookup's none);
    // a disk-tier get is one read + one shared allocation + path
    // assembly — bounded small, and crucially *no decode/re-encode*
    // of the frame on either path.
    let store = TieredStore::new(
        EndpointId::new(),
        TieredConfig { mem_high_watermark: 1 << 20, default_ttl_s: 0.0, spool_dir: None },
    )
    .unwrap();
    store.put("hot", frame.clone(), 0.0).unwrap();
    let (n, _) = allocs_during(|| {
        for _ in 0..N {
            std::hint::black_box(store.get("hot", 0.0).unwrap());
        }
    });
    assert_eq!(n, 0, "memory-tier get must be a handle clone, not a copy");
    let cold_store = TieredStore::new(
        EndpointId::new(),
        // Watermark 0: every frame spills to the disk tier (background
        // spiller) and never promotes back.
        TieredConfig { mem_high_watermark: 0, default_ttl_s: 0.0, spool_dir: None },
    )
    .unwrap();
    cold_store.put("cold", frame.clone(), 0.0).unwrap();
    // Wait out the background spill so the measurement below counts the
    // disk fetch path, not the spiller's own bookkeeping.
    assert!(cold_store.settle(std::time::Duration::from_secs(10)));
    let (n, got) = allocs_during(|| {
        (0..N).map(|_| cold_store.get("cold", 0.0).unwrap()).collect::<Vec<_>>()
    });
    assert!(
        n <= 16 * N,
        "{n} allocations for {N} disk-tier gets — fetch path is re-serializing"
    );
    assert!(got.iter().all(|g| g.as_slice() == frame.as_slice()), "byte-identical reload");
}
