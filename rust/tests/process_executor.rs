//! Process-executor backend end to end: real forked `funcx worker-child`
//! processes behind the executor abstraction. Crash, abort, and timeout
//! tasks must fail *typed* (`WorkerExited` / `WorkerSignaled` /
//! `Timeout`) with closed flight-recorder traces; healthy slots reuse
//! one child per slot with a measured (not sampled) start cost; and the
//! backend never leaks child processes or pipe fds.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use funcx::common::config::EndpointConfig;
use funcx::common::ids::{EndpointId, FunctionId, UserId};
use funcx::common::sync::Notify;
use funcx::common::task::{Payload, Task, TaskResult, TaskState};
use funcx::common::time::WallClock;
use funcx::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
use funcx::endpoint::{Manager, ManagerCtx};
use funcx::metrics::{FlightRecorder, LatencyBreakdown, TraceKind};
use funcx::runtime::{ProcessExecutor, ProcessExecutorConfig, WorkerExecutor};
use funcx::serialize::{pack, unpack, Buffer, Value};
use funcx::Error;

/// Serialize the tests in this binary: the fd-leak test counts
/// /proc/self/fd entries and concurrent children would skew it.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn exec_config() -> ProcessExecutorConfig {
    ProcessExecutorConfig::new(env!("CARGO_BIN_EXE_funcx"))
}

#[test]
fn child_runs_payloads_and_measures_start() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    assert_eq!(ex.backend(), "process");
    let started = ex.start_slot(1, 0).unwrap();
    let measured = started.expect("process backend measures starts");
    assert!(measured > 0.0, "spawn + handshake takes real time: {measured}");
    let (out, _exec_s) = ex.execute_in(1, 0, &Payload::Echo, &Value::Int(42)).unwrap();
    assert_eq!(out, Value::Int(42));
    // Same slot, same child: no second fork.
    let second = Value::Str("x".into());
    let (out, _) = ex.execute_in(1, 0, &Payload::Echo, &second).unwrap();
    assert_eq!(out, second);
    assert_eq!(ex.spawned(), 1);
    assert_eq!(ex.active_workers(), 1);
    ex.stop_slot(1, 0);
    assert_eq!(ex.active_workers(), 0);
    assert_eq!(ex.stopped(), 1);
}

#[test]
fn lazy_slot_spawns_on_first_execute() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    // No start_slot: execute_in forks on demand.
    let (out, _) = ex.execute_in(2, 7, &Payload::Echo, &Value::Int(7)).unwrap();
    assert_eq!(out, Value::Int(7));
    assert_eq!(ex.spawned(), 1);
}

#[test]
fn exit_task_fails_worker_exited() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    ex.start_slot(3, 0).unwrap();
    match ex.execute_in(3, 0, &Payload::Exit(3), &Value::Null) {
        Err(Error::WorkerExited { code }) => assert_eq!(code, 3),
        other => panic!("expected WorkerExited, got {other:?}"),
    }
    assert_eq!(ex.worker_faults(), 1);
    assert_eq!(ex.active_workers(), 0, "crashed slot must not return to the map");
    // The slot recovers: the next task on it forks a fresh child.
    let (out, _) = ex.execute_in(3, 0, &Payload::Echo, &Value::Int(1)).unwrap();
    assert_eq!(out, Value::Int(1));
}

#[cfg(unix)]
#[test]
fn abort_task_fails_worker_signaled() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    ex.start_slot(4, 0).unwrap();
    match ex.execute_in(4, 0, &Payload::Abort, &Value::Null) {
        Err(Error::WorkerSignaled { signal }) => assert_eq!(signal, 6, "SIGABRT"),
        other => panic!("expected WorkerSignaled, got {other:?}"),
    }
    assert_eq!(ex.worker_faults(), 1);
}

#[test]
fn overrunning_task_times_out_and_kills_child() {
    let _g = lock();
    let mut cfg = exec_config();
    cfg.task_timeout_s = 0.2;
    let ex = ProcessExecutor::new(cfg);
    ex.start_slot(5, 0).unwrap();
    let t0 = std::time::Instant::now();
    match ex.execute_in(5, 0, &Payload::Sleep(30.0), &Value::Null) {
        Err(Error::Timeout(_)) => {}
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "timeout must not wait the sleep out");
    assert_eq!(ex.timeouts(), 1);
    assert_eq!(ex.active_workers(), 0, "the overrunning child is killed, not reused");
}

/// The backend never leaks pipe fds: after spawning, crashing, timing
/// out, and stopping children, /proc/self/fd returns to its baseline.
#[cfg(target_os = "linux")]
#[test]
fn no_fd_leak_across_worker_lifecycles() {
    let _g = lock();
    let open_fds = || std::fs::read_dir("/proc/self/fd").unwrap().count();
    // One warm-up lifecycle so lazily-initialized runtime fds (stdio
    // locks, thread spawns) don't count against the baseline.
    {
        let ex = ProcessExecutor::new(exec_config());
        ex.start_slot(0, 0).unwrap();
        ex.execute_in(0, 0, &Payload::Echo, &Value::Int(0)).unwrap();
    }
    let baseline = open_fds();
    {
        let mut cfg = exec_config();
        cfg.task_timeout_s = 0.2;
        let ex = ProcessExecutor::new(cfg);
        for slot in 0..4 {
            ex.start_slot(9, slot).unwrap();
            let input = Value::Int(slot as i64);
            ex.execute_in(9, slot, &Payload::Echo, &input).unwrap();
        }
        // Crash one, time one out, stop one, leave one for Drop.
        let _ = ex.execute_in(9, 0, &Payload::Exit(2), &Value::Null);
        let _ = ex.execute_in(9, 1, &Payload::Sleep(30.0), &Value::Null);
        ex.stop_slot(9, 2);
    }
    // Reader threads close their pipe ends asynchronously after the
    // children die; poll briefly instead of asserting instantly.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut now_fds = open_fds();
    while now_fds > baseline && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
        now_fds = open_fds();
    }
    assert!(
        now_fds <= baseline,
        "fd leak: {now_fds} open fds after lifecycle, baseline {baseline}"
    );
}

fn process_ctx(
    results: std::sync::mpsc::Sender<Vec<TaskResult>>,
    recorder: Arc<FlightRecorder>,
) -> (ManagerCtx, Arc<ProcessExecutor>) {
    let ex = Arc::new(ProcessExecutor::new(exec_config()));
    let ctx = ManagerCtx {
        executor: ex.clone(),
        results,
        wake: Arc::new(Notify::new()),
        result_batch: 1,
        fabric: None,
        endpoint: None,
        max_result_bytes: EndpointConfig::default().max_result_bytes,
        clock: Arc::new(WallClock::new()),
        latency: Arc::new(LatencyBreakdown::new()),
        recorder,
        start_model: TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None),
        cold_start_scale: 0.001,
    };
    (ctx, ex)
}

fn mk_task(payload: Payload, input: Buffer) -> Task {
    Task::new(FunctionId::new(), EndpointId::new(), UserId::new(), None, payload, input)
}

/// A manager running on the process backend: tasks execute in real
/// children, the first start is cold with a *measured* cost (ColdStart
/// trace with `measured: true`), and the warm second task reuses the
/// same child.
#[test]
fn manager_on_process_backend_measures_cold_starts() {
    let _g = lock();
    let recorder = Arc::new(FlightRecorder::default());
    let (tx, rx) = channel();
    let (ctx, ex) = process_ctx(tx, recorder.clone());
    let m = Manager::spawn(1, 600.0, ctx, 21);

    let input = Value::Int(99);
    let mut task = mk_task(Payload::Echo, pack(&input, 0).unwrap());
    task.trace = Some(recorder.mint(task.id));
    let id = task.id;
    m.enqueue(vec![Arc::new(task)]);
    let r = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("task result")
        .pop()
        .unwrap();
    assert_eq!(r.state, TaskState::Success);
    assert!(r.cold_start);
    assert_eq!(unpack(&r.output).unwrap(), input);

    let trace = recorder.assemble(id).expect("traced task assembles");
    let cold = trace
        .events
        .iter()
        .find_map(|e| match &e.kind {
            TraceKind::ColdStart { seconds, measured, .. } => Some((*seconds, *measured)),
            _ => None,
        })
        .expect("cold start recorded");
    assert!(cold.1, "process backend reports measured starts");
    assert!(cold.0 > 0.0);
    assert!(m.view().cold_start_est_s > 0.0, "view advertises the measured EWMA");

    // Warm reuse: same child, no new fork.
    let task = mk_task(Payload::Echo, pack(&Value::Int(1), 0).unwrap());
    m.enqueue(vec![Arc::new(task)]);
    let r = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("second result")
        .pop()
        .unwrap();
    assert!(!r.cold_start);
    assert_eq!(ex.spawned(), 1, "warm task reuses the child");
    m.shutdown();
}

/// A crashing task through a real manager fails typed and its
/// flight-recorder trace closes with the matching terminal.
#[test]
fn crashing_task_closes_trace_with_typed_terminal() {
    let _g = lock();
    let recorder = Arc::new(FlightRecorder::default());
    let (tx, rx) = channel();
    let (ctx, _ex) = process_ctx(tx, recorder.clone());
    let m = Manager::spawn(1, 600.0, ctx, 22);

    for (payload, kind, needle) in [
        (Payload::Exit(3), "WorkerExited", "exited with status 3"),
        (Payload::Abort, "WorkerSignaled", "killed by signal"),
    ] {
        let mut task = mk_task(payload, Buffer::empty());
        task.trace = Some(recorder.mint(task.id));
        let id = task.id;
        m.enqueue(vec![Arc::new(task)]);
        let r = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("crashed task must produce a result, not hang")
            .pop()
            .unwrap();
        assert_eq!(r.state, TaskState::Failed);
        let msg = unpack(&r.output).unwrap();
        assert!(
            msg.as_str().unwrap_or("").contains(needle),
            "failure names the exit status: {msg:?}"
        );
        let trace = recorder.assemble(id).expect("trace assembles");
        match &trace.terminal().expect("crashed task's trace must close").kind {
            TraceKind::TaskFailed { error } => {
                assert_eq!(*error, kind, "typed terminal\n{}", trace.render())
            }
            other => panic!("terminal must be TaskFailed, got {other:?}"),
        }
    }
    m.shutdown();
}
