//! Process-executor backend end to end: real forked `funcx worker-child`
//! processes behind the executor abstraction. Crash, abort, and timeout
//! tasks must fail *typed* (`WorkerExited` / `WorkerSignaled` /
//! `Timeout`) with closed flight-recorder traces; healthy slots reuse
//! one child per slot with a measured (not sampled) start cost; and the
//! backend never leaks child processes or pipe fds.

use std::io::Cursor;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use funcx::common::config::EndpointConfig;
use funcx::common::ids::{EndpointId, FunctionId, UserId};
use funcx::common::sync::Notify;
use funcx::common::task::{Payload, Task, TaskResult, TaskState};
use funcx::common::time::WallClock;
use funcx::containers::{ContainerTech, SystemProfile, TABLE3_MODELS};
use funcx::endpoint::{Manager, ManagerCtx};
use funcx::metrics::{FlightRecorder, LatencyBreakdown, TraceKind};
use funcx::runtime::{
    match_reply, read_frame, write_frames, BatchItem, FrameOut, InFlight, ProcessExecutor,
    ProcessExecutorConfig, WorkerExecutor, KIND_REPLY, KIND_REQUEST, MAX_FRAME_BYTES,
};
use funcx::serialize::{pack, unpack, Buffer, Value};
use funcx::Error;

/// Serialize the tests in this binary: the fd-leak test counts
/// /proc/self/fd entries and concurrent children would skew it.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn exec_config() -> ProcessExecutorConfig {
    ProcessExecutorConfig::new(env!("CARGO_BIN_EXE_funcx"))
}

#[test]
fn child_runs_payloads_and_measures_start() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    assert_eq!(ex.backend(), "process");
    let started = ex.start_slot(1, 0).unwrap();
    let measured = started.expect("process backend measures starts");
    assert!(measured > 0.0, "spawn + handshake takes real time: {measured}");
    let (out, _exec_s) = ex.execute_in(1, 0, &Payload::Echo, &Value::Int(42)).unwrap();
    assert_eq!(out, Value::Int(42));
    // Same slot, same child: no second fork.
    let second = Value::Str("x".into());
    let (out, _) = ex.execute_in(1, 0, &Payload::Echo, &second).unwrap();
    assert_eq!(out, second);
    assert_eq!(ex.spawned(), 1);
    assert_eq!(ex.active_workers(), 1);
    ex.stop_slot(1, 0);
    assert_eq!(ex.active_workers(), 0);
    assert_eq!(ex.stopped(), 1);
}

#[test]
fn lazy_slot_spawns_on_first_execute() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    // No start_slot: execute_in forks on demand.
    let (out, _) = ex.execute_in(2, 7, &Payload::Echo, &Value::Int(7)).unwrap();
    assert_eq!(out, Value::Int(7));
    assert_eq!(ex.spawned(), 1);
}

#[test]
fn exit_task_fails_worker_exited() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    ex.start_slot(3, 0).unwrap();
    match ex.execute_in(3, 0, &Payload::Exit(3), &Value::Null) {
        Err(Error::WorkerExited { code }) => assert_eq!(code, 3),
        other => panic!("expected WorkerExited, got {other:?}"),
    }
    assert_eq!(ex.worker_faults(), 1);
    // The poisoned slot is restarted in place, not abandoned: a fresh
    // child already sits in the map, counted as a restart.
    assert_eq!(ex.active_workers(), 1, "crashed slot restarts in place");
    assert_eq!(ex.slot_restarts(), 1);
    // The restarted child serves the next task without another fork.
    let (out, _) = ex.execute_in(3, 0, &Payload::Echo, &Value::Int(1)).unwrap();
    assert_eq!(out, Value::Int(1));
    assert_eq!(ex.spawned(), 2, "one original fork + one in-place restart");
}

#[cfg(unix)]
#[test]
fn abort_task_fails_worker_signaled() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    ex.start_slot(4, 0).unwrap();
    match ex.execute_in(4, 0, &Payload::Abort, &Value::Null) {
        Err(Error::WorkerSignaled { signal }) => assert_eq!(signal, 6, "SIGABRT"),
        other => panic!("expected WorkerSignaled, got {other:?}"),
    }
    assert_eq!(ex.worker_faults(), 1);
}

#[test]
fn overrunning_task_times_out_and_kills_child() {
    let _g = lock();
    let mut cfg = exec_config();
    cfg.task_timeout_s = 0.2;
    let ex = ProcessExecutor::new(cfg);
    ex.start_slot(5, 0).unwrap();
    let t0 = std::time::Instant::now();
    match ex.execute_in(5, 0, &Payload::Sleep(30.0), &Value::Null) {
        Err(Error::Timeout(_)) => {}
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "timeout must not wait the sleep out");
    assert_eq!(ex.timeouts(), 1);
    // The overrunning child is killed, and the slot restarts in place
    // rather than leaking out of the worker map poisoned.
    assert_eq!(ex.active_workers(), 1, "killed slot restarts in place");
    assert_eq!(ex.slot_restarts(), 1);
}

/// The backend never leaks pipe fds: after spawning, crashing, timing
/// out, and stopping children, /proc/self/fd returns to its baseline.
#[cfg(target_os = "linux")]
#[test]
fn no_fd_leak_across_worker_lifecycles() {
    let _g = lock();
    let open_fds = || std::fs::read_dir("/proc/self/fd").unwrap().count();
    // One warm-up lifecycle so lazily-initialized runtime fds (stdio
    // locks, thread spawns) don't count against the baseline.
    {
        let ex = ProcessExecutor::new(exec_config());
        ex.start_slot(0, 0).unwrap();
        ex.execute_in(0, 0, &Payload::Echo, &Value::Int(0)).unwrap();
    }
    let baseline = open_fds();
    {
        let mut cfg = exec_config();
        cfg.task_timeout_s = 0.2;
        let ex = ProcessExecutor::new(cfg);
        for slot in 0..4 {
            ex.start_slot(9, slot).unwrap();
            let input = Value::Int(slot as i64);
            ex.execute_in(9, slot, &Payload::Echo, &input).unwrap();
        }
        // Crash one, time one out, stop one, leave one for Drop.
        let _ = ex.execute_in(9, 0, &Payload::Exit(2), &Value::Null);
        let _ = ex.execute_in(9, 1, &Payload::Sleep(30.0), &Value::Null);
        ex.stop_slot(9, 2);
    }
    // Reader threads close their pipe ends asynchronously after the
    // children die; poll briefly instead of asserting instantly.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut now_fds = open_fds();
    while now_fds > baseline && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
        now_fds = open_fds();
    }
    assert!(
        now_fds <= baseline,
        "fd leak: {now_fds} open fds after lifecycle, baseline {baseline}"
    );
}

/// Hostile v2 frames fail typed, never hang: truncated length
/// prefixes, truncated bodies, oversize claims, and frames too short
/// to carry a frame id + kind.
#[test]
fn hostile_frames_fail_typed_never_hang() {
    // Truncated length prefix (2 of 4 bytes).
    assert!(read_frame(&mut Cursor::new(vec![9u8, 0])).is_err());
    // Truncated body: claims 100 bytes, carries 10.
    let mut buf = 100u32.to_le_bytes().to_vec();
    buf.extend_from_slice(&[0u8; 10]);
    assert!(read_frame(&mut Cursor::new(buf)).is_err());
    // Oversize claim fails before anything that size is read.
    let claim = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
    assert!(read_frame(&mut Cursor::new(claim)).is_err());
    // Too short to carry the u64 id + u8 kind.
    let mut short = 8u32.to_le_bytes().to_vec();
    short.extend_from_slice(&[0u8; 8]);
    assert!(read_frame(&mut Cursor::new(short)).is_err());
}

/// Reply demux against the in-flight window: out-of-order completion
/// is the normal case; unknown ids, duplicate ids (already completed),
/// and non-reply kinds all fail typed instead of corrupting a slot.
#[test]
fn reply_demux_rejects_unknown_duplicate_and_bad_kind() {
    let t = Instant::now();
    let mut pending = vec![
        InFlight { item: 0, id: 5, sent: t },
        InFlight { item: 1, id: 6, sent: t },
        InFlight { item: 2, id: 7, sent: t },
    ];
    // Newest-first reply: out of order is fine.
    let pos = match_reply(&pending, 7, KIND_REPLY).unwrap();
    assert_eq!(pending.remove(pos).item, 2);
    // Unknown id.
    match match_reply(&pending, 99, KIND_REPLY) {
        Err(Error::Runtime(m)) => assert!(m.contains("unknown or duplicate"), "{m}"),
        other => panic!("expected typed desync, got {other:?}"),
    }
    // Duplicate: id 7 already left the window when it completed.
    match match_reply(&pending, 7, KIND_REPLY) {
        Err(Error::Runtime(m)) => assert!(m.contains("unknown or duplicate"), "{m}"),
        other => panic!("expected typed desync, got {other:?}"),
    }
    // Non-reply kind.
    match match_reply(&pending, 5, KIND_REQUEST) {
        Err(Error::Runtime(m)) => assert!(m.contains("unexpected frame kind"), "{m}"),
        other => panic!("expected typed desync, got {other:?}"),
    }
    // The survivors still demux at their positions.
    assert_eq!(match_reply(&pending, 5, KIND_REPLY).unwrap(), 0);
    assert_eq!(match_reply(&pending, 6, KIND_REPLY).unwrap(), 1);
}

/// Interleaved out-of-order replies over the real codec: two frames
/// written as one vectored batch, read back newest-first, each landing
/// on the item its frame id belongs to.
#[test]
fn interleaved_replies_route_to_their_frames() {
    let meta_a = pack(&Value::Int(1), 0).unwrap();
    let meta_b = pack(&Value::Int(2), 0).unwrap();
    let frames: [FrameOut<'_>; 2] = [
        (102, KIND_REPLY, meta_b.as_slice(), &[] as &[u8]),
        (101, KIND_REPLY, meta_a.as_slice(), &[] as &[u8]),
    ];
    let mut buf = Vec::new();
    write_frames(&mut buf, &frames).unwrap();

    let t = Instant::now();
    let mut pending = vec![
        InFlight { item: 0, id: 101, sent: t },
        InFlight { item: 1, id: 102, sent: t },
    ];
    let mut completed = Vec::new();
    let mut r = Cursor::new(buf);
    while let Some((id, kind, body)) = read_frame(&mut r).unwrap() {
        let pos = match_reply(&pending, id, kind).unwrap();
        let f = pending.remove(pos);
        completed.push((f.item, unpack(&body).unwrap()));
    }
    assert_eq!(completed, vec![(1, Value::Int(2)), (0, Value::Int(1))]);
    assert!(pending.is_empty(), "every in-flight frame found its reply");
}

/// Eight echoes through one child with the default depth-4 window:
/// every item completes Ok with its own output, on a single fork.
#[test]
fn pipelined_batch_completes_every_item_on_one_child() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    ex.start_slot(10, 0).unwrap();
    let items: Vec<BatchItem> = (0..8)
        .map(|i| BatchItem { payload: Payload::Echo, input: pack(&Value::Int(i), 0).unwrap() })
        .collect();
    // `vec![None; n]` needs Clone, which `Error` deliberately lacks.
    let mut done: Vec<Option<funcx::Result<(Buffer, f64)>>> =
        (0..items.len()).map(|_| None).collect();
    ex.execute_batch(10, 0, &items, &mut |i, r| done[i] = Some(r));
    for (i, slot) in done.iter().enumerate() {
        let result = slot.as_ref().expect("every item completes exactly once");
        let (frame, _) = result.as_ref().expect("echo succeeds");
        assert_eq!(unpack(frame).unwrap(), Value::Int(i as i64));
    }
    assert_eq!(ex.spawned(), 1, "one child served the whole window");
    assert_eq!(ex.active_workers(), 1);
    assert_eq!(ex.worker_faults(), 0);
}

/// Acceptance: a child crash with three frames in flight fails exactly
/// those three tasks typed, restarts the slot in place, and the
/// replacement serves subsequent tasks.
#[test]
fn crash_mid_window_fails_in_flight_typed_and_restarts_slot() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    ex.start_slot(12, 0).unwrap();
    let items = vec![
        BatchItem { payload: Payload::Exit(7), input: Buffer::empty() },
        BatchItem { payload: Payload::Echo, input: pack(&Value::Int(1), 0).unwrap() },
        BatchItem { payload: Payload::Echo, input: pack(&Value::Int(2), 0).unwrap() },
    ];
    let mut errs: Vec<Option<funcx::Result<(Buffer, f64)>>> = (0..3).map(|_| None).collect();
    ex.execute_batch(12, 0, &items, &mut |i, r| errs[i] = Some(r));
    for e in &errs {
        match e.as_ref().expect("all three in-flight frames complete") {
            Err(Error::WorkerExited { code }) => assert_eq!(*code, 7),
            other => panic!("expected WorkerExited(7), got {other:?}"),
        }
    }
    assert_eq!(ex.worker_faults(), 1);
    assert_eq!(ex.slot_restarts(), 1);
    assert_eq!(ex.active_workers(), 1, "slot restarted in place");
    let (out, _) = ex.execute_in(12, 0, &Payload::Echo, &Value::Int(3)).unwrap();
    assert_eq!(out, Value::Int(3));
    assert_eq!(ex.spawned(), 2, "original child + one in-place restart only");
}

/// A binary that is not a worker child (prints text, exits) fails the
/// spawn typed — never hangs — and leaves no live worker behind.
#[test]
fn hostile_child_binary_fails_spawn_typed() {
    let _g = lock();
    let mut cfg = exec_config();
    cfg.binary = "/bin/echo".into();
    let ex = ProcessExecutor::new(cfg);
    let t0 = Instant::now();
    match ex.start_slot(13, 0) {
        Err(Error::WorkerExited { .. }) => {}
        other => panic!("expected typed WorkerExited, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(10), "hostile child must fail fast");
    assert_eq!(ex.active_workers(), 0);
    // The lazy-spawn path types the same failure instead of hanging.
    match ex.execute_in(13, 0, &Payload::Echo, &Value::Int(1)) {
        Err(Error::WorkerExited { .. }) => {}
        other => panic!("expected typed WorkerExited, got {other:?}"),
    }
}

/// Lazily spawned children report their measured start cost through
/// `drain_start_costs` instead of discarding it.
#[test]
fn lazy_spawn_costs_are_drained_not_discarded() {
    let _g = lock();
    let ex = ProcessExecutor::new(exec_config());
    let (out, _) = ex.execute_in(14, 0, &Payload::Echo, &Value::Int(9)).unwrap();
    assert_eq!(out, Value::Int(9));
    let costs = ex.drain_start_costs(14);
    assert_eq!(costs.len(), 1, "one lazy spawn parks one measured cost");
    assert!(costs[0] > 0.0);
    assert!(ex.drain_start_costs(14).is_empty(), "drain consumes");
    // start_slot costs are returned directly to the caller, not parked.
    ex.start_slot(14, 1).unwrap();
    assert!(ex.drain_start_costs(14).is_empty());
}

fn process_ctx(
    results: std::sync::mpsc::Sender<Vec<TaskResult>>,
    recorder: Arc<FlightRecorder>,
) -> (ManagerCtx, Arc<ProcessExecutor>) {
    let ex = Arc::new(ProcessExecutor::new(exec_config()));
    let ctx = ManagerCtx {
        executor: ex.clone(),
        results,
        wake: Arc::new(Notify::new()),
        result_batch: 1,
        fabric: None,
        endpoint: None,
        max_result_bytes: EndpointConfig::default().max_result_bytes,
        clock: Arc::new(WallClock::new()),
        latency: Arc::new(LatencyBreakdown::new()),
        recorder,
        start_model: TABLE3_MODELS.lookup(SystemProfile::Local, ContainerTech::None),
        cold_start_scale: 0.001,
        pipeline_depth: EndpointConfig::default().worker_pipeline_depth,
    };
    (ctx, ex)
}

fn mk_task(payload: Payload, input: Buffer) -> Task {
    Task::new(FunctionId::new(), EndpointId::new(), UserId::new(), None, payload, input)
}

/// A manager running on the process backend: tasks execute in real
/// children, the first start is cold with a *measured* cost (ColdStart
/// trace with `measured: true`), and the warm second task reuses the
/// same child.
#[test]
fn manager_on_process_backend_measures_cold_starts() {
    let _g = lock();
    let recorder = Arc::new(FlightRecorder::default());
    let (tx, rx) = channel();
    let (ctx, ex) = process_ctx(tx, recorder.clone());
    let m = Manager::spawn(1, 600.0, ctx, 21);

    let input = Value::Int(99);
    let mut task = mk_task(Payload::Echo, pack(&input, 0).unwrap());
    task.trace = Some(recorder.mint(task.id));
    let id = task.id;
    m.enqueue(vec![Arc::new(task)]);
    let r = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("task result")
        .pop()
        .unwrap();
    assert_eq!(r.state, TaskState::Success);
    assert!(r.cold_start);
    assert_eq!(unpack(&r.output).unwrap(), input);

    let trace = recorder.assemble(id).expect("traced task assembles");
    let cold = trace
        .events
        .iter()
        .find_map(|e| match &e.kind {
            TraceKind::ColdStart { seconds, measured, .. } => Some((*seconds, *measured)),
            _ => None,
        })
        .expect("cold start recorded");
    assert!(cold.1, "process backend reports measured starts");
    assert!(cold.0 > 0.0);
    assert!(m.view().cold_start_est_s > 0.0, "view advertises the measured EWMA");

    // Warm reuse: same child, no new fork.
    let task = mk_task(Payload::Echo, pack(&Value::Int(1), 0).unwrap());
    m.enqueue(vec![Arc::new(task)]);
    let r = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("second result")
        .pop()
        .unwrap();
    assert!(!r.cold_start);
    assert_eq!(ex.spawned(), 1, "warm task reuses the child");
    m.shutdown();
}

/// A crashing task through a real manager fails typed and its
/// flight-recorder trace closes with the matching terminal.
#[test]
fn crashing_task_closes_trace_with_typed_terminal() {
    let _g = lock();
    let recorder = Arc::new(FlightRecorder::default());
    let (tx, rx) = channel();
    let (ctx, _ex) = process_ctx(tx, recorder.clone());
    let m = Manager::spawn(1, 600.0, ctx, 22);

    for (payload, kind, needle) in [
        (Payload::Exit(3), "WorkerExited", "exited with status 3"),
        (Payload::Abort, "WorkerSignaled", "killed by signal"),
    ] {
        let mut task = mk_task(payload, Buffer::empty());
        task.trace = Some(recorder.mint(task.id));
        let id = task.id;
        m.enqueue(vec![Arc::new(task)]);
        let r = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("crashed task must produce a result, not hang")
            .pop()
            .unwrap();
        assert_eq!(r.state, TaskState::Failed);
        let msg = unpack(&r.output).unwrap();
        assert!(
            msg.as_str().unwrap_or("").contains(needle),
            "failure names the exit status: {msg:?}"
        );
        let trace = recorder.assemble(id).expect("trace assembles");
        match &trace.terminal().expect("crashed task's trace must close").kind {
            TraceKind::TaskFailed { error } => {
                assert_eq!(*error, kind, "typed terminal\n{}", trace.render())
            }
            other => panic!("terminal must be TaskFailed, got {other:?}"),
        }
    }
    m.shutdown();
}

/// Acceptance, manager level: a crash with three frames in flight fails
/// exactly the in-flight tasks typed, closes all three flight-recorder
/// traces, and the restarted slot serves subsequent tasks.
#[test]
fn manager_crash_with_three_in_flight_closes_traces_and_recovers() {
    let _g = lock();
    let recorder = Arc::new(FlightRecorder::default());
    let (tx, rx) = channel();
    let (ctx, ex) = process_ctx(tx, recorder.clone());
    let m = Manager::spawn(1, 600.0, ctx, 23);

    let mut ids = Vec::new();
    let batch: Vec<Arc<Task>> = [Payload::Exit(7), Payload::Echo, Payload::Echo]
        .into_iter()
        .map(|p| {
            let input = if p == Payload::Echo {
                pack(&Value::Int(1), 0).unwrap()
            } else {
                Buffer::empty()
            };
            let mut t = mk_task(p, input);
            t.trace = Some(recorder.mint(t.id));
            ids.push(t.id);
            Arc::new(t)
        })
        .collect();
    m.enqueue(batch);

    let mut results = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(30);
    while results.len() < 3 && Instant::now() < deadline {
        if let Ok(b) = rx.recv_timeout(Duration::from_millis(100)) {
            results.extend(b);
        }
    }
    assert_eq!(results.len(), 3, "every in-flight task produces a result");
    for r in &results {
        assert_eq!(r.state, TaskState::Failed);
        let msg = unpack(&r.output).unwrap();
        assert!(
            msg.as_str().unwrap_or("").contains("exited with status 7"),
            "failure carries the child's typed status: {msg:?}"
        );
    }
    for id in &ids {
        let trace = recorder.assemble(*id).expect("trace assembles");
        match &trace.terminal().expect("in-flight task's trace must close").kind {
            TraceKind::TaskFailed { error } => {
                assert_eq!(*error, "WorkerExited", "typed terminal\n{}", trace.render())
            }
            other => panic!("terminal must be TaskFailed, got {other:?}"),
        }
    }
    assert_eq!(ex.slot_restarts(), 1);

    // The restarted slot keeps serving.
    let task = mk_task(Payload::Echo, pack(&Value::Int(5), 0).unwrap());
    m.enqueue(vec![Arc::new(task)]);
    let r = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("post-crash task completes")
        .pop()
        .unwrap();
    assert_eq!(r.state, TaskState::Success);
    assert_eq!(ex.spawned(), 2, "original child + one in-place restart only");
    m.shutdown();
}
