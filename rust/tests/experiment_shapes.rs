//! Integration tests asserting the *shape* of every reproduced table and
//! figure (DESIGN.md §4's accepted-shape criteria). These are the
//! regression guards for the evaluation harnesses.

use funcx::data::Transport;
use funcx::experiments as exp;
use funcx::sim::SimProfile;

/// Fig. 4(a): completion decreases with containers then flattens near
/// 256 (no-op) / 2048 (1 s sleep) — the agent-dispatch bound.
#[test]
fn fig4a_strong_scaling_knees() {
    let counts = [64, 128, 256, 1024, 4096];
    let noop = exp::fig4_strong(SimProfile::theta(), 50_000, 0.0, &counts);
    assert!(noop[0].completion_s > 1.5 * noop[2].completion_s, "64 -> 256 must speed up");
    assert!(
        noop[2].completion_s < 1.3 * noop[4].completion_s
            && noop[4].completion_s < 1.3 * noop[2].completion_s,
        "no-op flat past 256: {} vs {}",
        noop[2].completion_s,
        noop[4].completion_s
    );

    let sleep = exp::fig4_strong(SimProfile::theta(), 50_000, 1.0, &[256, 2048, 8192]);
    assert!(sleep[0].completion_s > 1.5 * sleep[1].completion_s, "sleep scales past 256");
    assert!(
        sleep[1].completion_s < 1.3 * sleep[2].completion_s,
        "sleep flat past 2048: {} vs {}",
        sleep[1].completion_s,
        sleep[2].completion_s
    );
}

/// Fig. 4(b): weak-scaling no-op completion grows with container count;
/// sleep stays ~flat to 2048; Cori reaches 131 072 containers / 1.3 M
/// tasks (the paper's headline scale).
#[test]
fn fig4b_weak_scaling_shapes() {
    let noop = exp::fig4_weak(SimProfile::cori(), 10, 0.0, &[1024, 16_384, 131_072]);
    assert!(noop[2].completion_s > noop[1].completion_s);
    assert!(noop[1].completion_s > noop[0].completion_s);
    assert_eq!(noop[2].containers, 131_072);

    let sleep = exp::fig4_weak(SimProfile::theta(), 10, 1.0, &[256, 2048]);
    let ratio = sleep[1].completion_s / sleep[0].completion_s;
    assert!(ratio < 1.5, "1s-sleep weak scaling ~flat to 2048: ratio {ratio}");
}

/// §7.2.3: peak throughputs match the paper's calibration.
#[test]
fn throughput_matches_calibration() {
    let theta = exp::peak_throughput(SimProfile::theta());
    let cori = exp::peak_throughput(SimProfile::cori());
    assert!((theta - 1694.0).abs() / 1694.0 < 0.15, "theta {theta}");
    assert!((cori - 1466.0).abs() / 1466.0 < 0.15, "cori {cori}");
}

/// Fig. 5: ordering MPI < ZMQ <= in-memory << sharedFS at small sizes;
/// convergence at 1 GB.
#[test]
fn fig5_ordering_and_convergence() {
    let pts = exp::fig5_transfer(&[4096, 1 << 30]);
    let get = |t: Transport, size: usize| {
        pts.iter()
            .find(|p| {
                p.transport == t
                    && p.size_bytes == size
                    && matches!(p.pattern, funcx::data::CommPattern::PointToPoint)
            })
            .unwrap()
            .time_s
    };
    let small = 4096;
    assert!(get(Transport::Mpi, small) < get(Transport::ZeroMq, small));
    assert!(get(Transport::ZeroMq, small) < get(Transport::InMemoryStore, small));
    assert!(get(Transport::InMemoryStore, small) < get(Transport::SharedFs, small));
    assert!(get(Transport::SharedFs, small) / get(Transport::Mpi, small) > 20.0);
    let big = 1 << 30;
    assert!(get(Transport::SharedFs, big) / get(Transport::Mpi, big) < 6.0);
}

/// Table 1: shuffle speedups and Sort-vs-WordCount improvement ordering.
#[test]
fn table1_claims() {
    let rows = exp::table1_mapreduce();
    let phases = |app: &str, t: Transport| {
        rows.iter().find(|r| r.app == app && r.transport == t).unwrap().phases
    };
    let speedup = phases("Sort", Transport::SharedFs).intermediate_read_s
        / phases("Sort", Transport::InMemoryStore).intermediate_read_s;
    assert!((1.5..6.0).contains(&speedup), "sort shuffle-read speedup {speedup}");
    let imp = |app: &str| {
        let r = phases(app, Transport::InMemoryStore).total();
        let f = phases(app, Transport::SharedFs).total();
        (f - r) / f
    };
    assert!(imp("Sort") > imp("WordCount"));
}

/// Table 2: Redis wins every stage; contended result-write dominates FS.
#[test]
fn table2_claims() {
    let rows = exp::table2_colmena();
    let redis = rows.iter().find(|r| r.transport == Transport::InMemoryStore).unwrap().stages;
    let fs = rows.iter().find(|r| r.transport == Transport::SharedFs).unwrap().stages;
    assert!(redis.input_write_s < fs.input_write_s);
    assert!(redis.input_read_s < fs.input_read_s);
    assert!(redis.result_write_s < fs.result_write_s);
    assert!(redis.result_read_s < fs.result_read_s);
    assert!(fs.result_write_s > fs.input_write_s * 2.0);
    // Near the paper's cells.
    assert!((fs.result_write_s - 0.2447).abs() < 0.08, "{}", fs.result_write_s);
    assert!((redis.input_write_s - 0.00715).abs() < 0.004, "{}", redis.input_write_s);
}

/// Table 3: sampled stats close to the published min/max/mean.
#[test]
fn table3_close_to_paper() {
    let rows = exp::table3_containers(20_000, 11);
    let expect = [
        ("theta", "singularity", 9.83, 14.06, 10.40),
        ("cori", "shifter", 7.25, 31.26, 8.49),
        ("ec2", "docker", 1.74, 1.88, 1.79),
        ("ec2", "singularity", 1.19, 1.26, 1.22),
    ];
    for (sys, tech, min, max, mean) in expect {
        let r = rows
            .iter()
            .find(|r| r.system == sys && r.container == tech)
            .unwrap_or_else(|| panic!("row {sys}/{tech}"));
        assert!(r.min_s >= min - 0.01, "{sys} min {}", r.min_s);
        assert!(r.max_s <= max + 0.01, "{sys} max {}", r.max_s);
        assert!((r.mean_s - mean).abs() / mean < 0.12, "{sys} mean {}", r.mean_s);
    }
}

/// Figs. 6–7: warming-aware beats random on completion AND cold starts;
/// the benefit decays as function duration grows (the paper's claim).
#[test]
fn fig6_fig7_claims() {
    let pts = exp::fig6_fig7_routing(&[3000], &[0.0, 5.0, 20.0], 13);
    for p in &pts {
        assert!(
            p.warming_completion_s <= p.random_completion_s,
            "warming must not lose at duration {}",
            p.duration_s
        );
        assert!(p.warming_cold_starts < p.random_cold_starts);
    }
    let gain = |p: &exp::RoutingPoint| {
        (p.random_completion_s - p.warming_completion_s) / p.random_completion_s
    };
    assert!(gain(&pts[0]) > gain(&pts[2]), "benefit decays with duration");
    // Fig. 7's relative claim: random's cold starts grow with the batch
    // and stay a large multiple of warming-aware's.
    assert!(pts[0].warming_cold_starts < 1400);
    assert!(pts[0].random_cold_starts > 2 * pts[0].warming_cold_starts);
}

/// §7.5: batching 10x+ speedup, magnitudes near the paper's 6.7 s/118 s.
#[test]
fn batching_claims() {
    let r = exp::batching_ablation();
    assert!((4.0..12.0).contains(&r.batched_s), "batched {}", r.batched_s);
    assert!((90.0..150.0).contains(&r.unbatched_s), "unbatched {}", r.unbatched_s);
    assert!(r.unbatched_s / r.batched_s > 10.0);
}
