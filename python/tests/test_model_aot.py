"""L2 + AOT tests: model graphs produce the contracted shapes, lower to
HLO text cleanly, and the artifact manifest is deterministic."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_surrogate_shapes():
    args = [jnp.zeros(s.shape, s.dtype) for s in model.surrogate_example_args()]
    (out,) = model.surrogate_infer(*args)
    assert out.shape == (model.SURROGATE_BATCH, model.SURROGATE_D_OUT)
    assert out.dtype == jnp.float32


def test_surrogate_matches_ref():
    r = np.random.default_rng(3)
    args = [
        jnp.asarray(r.standard_normal(s.shape).astype(np.float32) * 0.1)
        for s in model.surrogate_example_args()
    ]
    (got,) = model.surrogate_infer(*args)
    want = ref.mlp_block_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


def test_stills_shapes_and_total():
    r = np.random.default_rng(4)
    img = r.standard_normal((model.STILLS_H, model.STILLS_W)).astype(np.float32)
    img[100, 100] = 99.0
    counts, bg, total = model.stills_process(
        jnp.asarray(img), jnp.asarray([5.0], np.float32)
    )
    gh = model.STILLS_H // model.STILLS_BH
    gw = model.STILLS_W // model.STILLS_BW
    assert counts.shape == (gh, gw) and bg.shape == (gh, gw)
    assert float(total) == pytest.approx(float(jnp.sum(counts)))
    assert float(total) >= 1.0


def test_reducer_shapes():
    ids = jnp.zeros(model.REDUCER_N, jnp.int32)
    vals = jnp.ones(model.REDUCER_N, jnp.float32)
    (sums,) = model.reduce_shuffle(ids, vals)
    assert sums.shape == (model.REDUCER_SEGMENTS,)
    assert float(sums[0]) == model.REDUCER_N


@pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
def test_lowering_emits_hlo_text(name):
    fn, example_args = model.ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*example_args())
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_lower_all_manifest(tmp_path):
    m1 = aot.lower_all(tmp_path)
    assert set(m1) == set(model.ARTIFACTS)
    for name, entry in m1.items():
        assert (tmp_path / entry["file"]).exists()
    # Determinism: re-lowering yields identical hashes.
    m2 = aot.lower_all(tmp_path)
    assert m1 == m2
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest == m2
