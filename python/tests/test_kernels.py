"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes/seeds. These run under interpret=True on CPU."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import mlp_block, peak_detect, segment_sum, tiled_matmul
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=12, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# tiled_matmul
# ---------------------------------------------------------------------------
@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    ki=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(mi, ni, ki, seed):
    bm = bn = bk = 128
    m, n, k = mi * bm, ni * bn, ki * bk
    r = rng(seed)
    x = r.standard_normal((m, k), dtype=np.float32)
    w = r.standard_normal((k, n), dtype=np.float32)
    got = tiled_matmul(jnp.asarray(x), jnp.asarray(w))
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@given(
    bm=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_block_shape_invariance(bm, bk, seed):
    """Different tilings of the same problem give the same numbers."""
    m, n, k = 128, 128, 128
    r = rng(seed)
    x = jnp.asarray(r.standard_normal((m, k), dtype=np.float32))
    w = jnp.asarray(r.standard_normal((k, n), dtype=np.float32))
    a = tiled_matmul(x, w, bm=bm, bn=128, bk=bk)
    b = tiled_matmul(x, w)  # default 128^3 tiling
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_matmul_rejects_unaligned():
    x = jnp.zeros((100, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    with pytest.raises(AssertionError):
        tiled_matmul(x, w)


def test_matmul_identity():
    x = jnp.asarray(rng(0).standard_normal((128, 128), dtype=np.float32))
    eye = jnp.eye(128, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(tiled_matmul(x, eye)), np.asarray(x), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# mlp_block (the surrogate's full head)
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1))
def test_mlp_block_matches_ref(seed):
    r = rng(seed)
    x = jnp.asarray(r.standard_normal((128, 256), dtype=np.float32) * 0.1)
    w1 = jnp.asarray(r.standard_normal((256, 512), dtype=np.float32) * 0.05)
    b1 = jnp.asarray(r.standard_normal(512, dtype=np.float32) * 0.05)
    w2 = jnp.asarray(r.standard_normal((512, 128), dtype=np.float32) * 0.05)
    b2 = jnp.asarray(r.standard_normal(128, dtype=np.float32) * 0.05)
    got = mlp_block(x, w1, b1, w2, b2)
    want = ref.mlp_block_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# peak_detect
# ---------------------------------------------------------------------------
@given(
    gh=st.integers(1, 2),
    gw=st.integers(1, 2),
    bh=st.sampled_from([64, 128]),
    thresh=st.floats(0.5, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_peak_detect_matches_ref(gh, gw, bh, thresh, seed):
    bw = bh
    h, w = gh * bh, gw * bw
    r = rng(seed)
    img = r.standard_normal((h, w)).astype(np.float32)
    # Plant a few unambiguous peaks.
    for _ in range(5):
        y, x = r.integers(1, h - 1), r.integers(1, w - 1)
        img[y, x] = 50.0 + r.random()
    t = np.array([thresh], dtype=np.float32)
    got_c, got_b = peak_detect(jnp.asarray(img), jnp.asarray(t), bh=bh, bw=bw)
    want_c, want_b = ref.peak_detect_ref(jnp.asarray(img), jnp.asarray(t), bh, bw)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), atol=0)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b), rtol=1e-5, atol=1e-5)


def test_peak_detect_counts_planted_peaks():
    """Isolated bright pixels in tile interiors are counted exactly."""
    img = np.zeros((256, 256), np.float32)
    spots = [(10, 10), (50, 200), (130, 130), (200, 60)]
    for y, x in spots:
        img[y, x] = 100.0
    counts, bg = peak_detect(jnp.asarray(img), jnp.asarray([1.0], np.float32), bh=256, bw=256)
    assert float(counts[0, 0]) == len(spots)
    assert float(bg[0, 0]) == pytest.approx(0.0, abs=1e-6)


def test_peak_detect_threshold_excludes():
    img = np.zeros((128, 128), np.float32)
    img[5, 5] = 0.5  # below threshold
    counts, _ = peak_detect(jnp.asarray(img), jnp.asarray([1.0], np.float32), bh=128, bw=128)
    assert float(counts[0, 0]) == 0.0


# ---------------------------------------------------------------------------
# segment_sum
# ---------------------------------------------------------------------------
@given(
    blocks=st.integers(1, 4),
    num_segments=st.sampled_from([16, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_sum_matches_ref(blocks, num_segments, seed):
    n = blocks * 1024
    r = rng(seed)
    ids = r.integers(0, num_segments, size=n).astype(np.int32)
    vals = r.standard_normal(n).astype(np.float32)
    got = segment_sum(jnp.asarray(ids), jnp.asarray(vals), num_segments)
    want = ref.segment_sum_ref(jnp.asarray(ids), jnp.asarray(vals), num_segments)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_segment_sum_conservation():
    """Total mass is conserved across buckets."""
    r = rng(7)
    ids = r.integers(0, 256, size=4096).astype(np.int32)
    vals = r.random(4096).astype(np.float32)
    got = segment_sum(jnp.asarray(ids), jnp.asarray(vals), 256)
    assert float(jnp.sum(got)) == pytest.approx(float(vals.sum()), rel=1e-4)


def test_segment_sum_single_bucket():
    ids = np.zeros(1024, np.int32)
    vals = np.ones(1024, np.float32)
    got = segment_sum(jnp.asarray(ids), jnp.asarray(vals), 4)
    np.testing.assert_allclose(np.asarray(got), [1024.0, 0.0, 0.0, 0.0])
