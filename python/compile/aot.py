"""AOT entry point: lower every L2 graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Re-running is idempotent; `make artifacts` skips it when inputs are older
than the outputs.
"""

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {}
    for name, (fn, example_args) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args())
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = {
            "file": path.name,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "num_params": len(example_args()),
        }
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    lower_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
