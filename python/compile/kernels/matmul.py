"""Tiled matmul (+bias +GELU) Pallas kernel — the AlphaFold-as-a-service
surrogate's compute hot-spot.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks (M/bm,
N/bn, K/bk); for each (i, j) output tile the K dimension is streamed in
bk-sized slabs so the three resident blocks (x, w, out) fit comfortably in
VMEM (3 x 128x128 f32 = 192 KiB of ~16 MB/core). Block shapes default to
128x128 — the MXU systolic array's native tile — so a real-TPU lowering
would hit full MXU occupancy; on CPU we run interpret=True, which executes
the same schedule with numpy.

The output block index map ignores k, so the same (i, j) block stays
resident across the K loop and accumulates in place — the canonical
Pallas K-streaming pattern (no scratch buffer needed).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """Grid = (M/bm, N/bn, K/bk); accumulate K slabs into the output tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def tiled_matmul(x, w, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """``x @ w`` via a K-streaming tiled Pallas kernel.

    Shapes must be multiples of the block sizes; the L2 model pads to
    these boundaries at trace time so the AOT artifact sees aligned shapes.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} != {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k2},{n}) not aligned to blocks ({bm},{bn},{bk})"
    )
    n_k = k // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _gelu(x):
    """tanh-approximation GELU (matches ref.py exactly)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def mlp_block(x, w1, b1, w2, b2):
    """Two-layer MLP head: gelu(x@w1 + b1) @ w2 + b2, both matmuls Pallas.

    Block schedule (perf pass, see EXPERIMENTS.md §Perf): at these layer
    sizes the full operands fit VMEM (layer 1 resident set: 128x256 +
    256x512 + 128x512 f32 ~ 0.9 MB of ~16 MB), so full-width blocks give
    a single-trip grid — 2.7x faster than 128^3 tiling under the XLA CPU
    lowering and the correct TPU schedule as well (no K-loop overhead,
    MXU-aligned 128-multiples).
    """
    m, k1 = x.shape
    n1 = w1.shape[1]
    h = tiled_matmul(x, w1, bm=m, bn=n1, bk=k1) + b1[None, :]
    h = _gelu(h)
    k2, n2 = w2.shape
    return tiled_matmul(h, w2, bm=m, bn=n2, bk=k2) + b2[None, :]
