"""Layer-1 Pallas kernels for the funcX compute payloads.

Each kernel is authored TPU-style (VMEM-sized blocks, MXU-shaped matmul
tiles, BlockSpec HBM<->VMEM schedules) but lowered with ``interpret=True``
so the resulting HLO runs on the CPU PJRT plugin that the Rust runtime
loads. ``ref.py`` holds the pure-jnp oracles used by pytest.
"""

from .matmul import mlp_block, tiled_matmul  # noqa: F401
from .reduce import segment_sum  # noqa: F401
from .stencil import peak_detect  # noqa: F401
