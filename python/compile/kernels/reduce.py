"""Segment-sum Pallas kernel — the MapReduce shuffle-aggregation stand-in.

WordCount/Sort reducers (paper §7.3.1) aggregate keyed chunks. We model the
reducer's hot loop as a segment sum: values[i] accumulates into
out[segment_ids[i]]. The grid streams the value array through VMEM in
1-D blocks; each block scatters into the (num_segments,) output, which
stays resident across the whole grid (block index map is constant) — the
same revisit-accumulate schedule as the matmul kernel's K loop.

On TPU the scatter is a one-hot matmul (segment one-hot [bs, S] x values
[bs] on the MXU); we keep that formulation so the interpret-mode HLO and a
real Mosaic lowering share structure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _segsum_kernel(ids_ref, vals_ref, o_ref, *, num_segments: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]
    vals = vals_ref[...]
    # One-hot scatter-add: [S, bs] @ [bs] -> [S]; MXU-friendly on TPU.
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (num_segments, ids.shape[0]), 0)
        == ids[None, :]
    ).astype(jnp.float32)
    o_ref[...] += onehot @ vals


def segment_sum(segment_ids, values, num_segments: int, *, block: int = BLOCK):
    """Sum ``values`` into ``num_segments`` buckets keyed by ``segment_ids``.

    Args:
      segment_ids: i32[N] in [0, num_segments); N % block == 0.
      values: f32[N].

    Returns:
      f32[num_segments].
    """
    (n,) = values.shape
    assert segment_ids.shape == (n,)
    assert n % block == 0, f"N={n} not aligned to block={block}"
    kernel = functools.partial(_segsum_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.float32),
        interpret=True,
    )(segment_ids, values)
