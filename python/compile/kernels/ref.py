"""Pure-jnp oracles for the Pallas kernels. pytest asserts allclose
between each kernel and its oracle over hypothesis-driven shape sweeps —
the core L1 correctness signal."""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.matmul(x, w)


def gelu_ref(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def mlp_block_ref(x, w1, b1, w2, b2):
    h = gelu_ref(jnp.matmul(x, w1) + b1[None, :])
    return jnp.matmul(h, w2) + b2[None, :]


def peak_detect_ref(img, thresh, bh, bw):
    """Per-tile local-max counts + sub-threshold background means.

    Mirrors the kernel's semantics exactly: 8-neighbour >= test with
    wrapped (per-tile jnp.roll) neighbours, tile rim masked out.
    """
    h, w = img.shape
    gh, gw = h // bh, w // bw
    t = thresh[0]
    counts = jnp.zeros((gh, gw), jnp.float32)
    bgs = jnp.zeros((gh, gw), jnp.float32)
    for i in range(gh):
        for j in range(gw):
            tile = img[i * bh : (i + 1) * bh, j * bw : (j + 1) * bw]
            is_max = tile > t
            for dy, dx in ((-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1)):
                is_max &= tile >= jnp.roll(tile, (dy, dx), axis=(0, 1))
            rows = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
            interior = (rows > 0) & (rows < bh - 1) & (cols > 0) & (cols < bw - 1)
            is_max &= interior
            counts = counts.at[i, j].set(jnp.sum(is_max.astype(jnp.float32)))
            below = tile <= t
            n_below = jnp.sum(below.astype(jnp.float32))
            bg = jnp.where(
                n_below > 0,
                jnp.sum(jnp.where(below, tile, 0.0)) / jnp.maximum(n_below, 1.0),
                0.0,
            )
            bgs = bgs.at[i, j].set(bg)
    return counts, bgs


def segment_sum_ref(segment_ids, values, num_segments):
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
