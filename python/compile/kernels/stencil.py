"""Bragg-peak detection Pallas kernel — the SSX ``process_stills`` stand-in.

Fixed-target serial crystallography (paper §2) analyses detector stills:
find local diffraction maxima above a threshold and report a per-tile peak
count plus a background estimate. We express that as a 2-D stencil over the
detector image.

TPU mapping: BlockSpec tiles the image into (bh, bw) VMEM-resident blocks
with a 1-pixel halo handled by shifted in-tile comparisons (jnp.roll inside
the block; block interiors dominate at 256x256, and the L2 wrapper pads the
image edge with -inf so borders never produce spurious peaks). Each grid
step reads one HBM tile into VMEM, does 8 shifted compares + reductions on
the VPU, and writes a (1, 1) count and background cell — a pure
streaming schedule with O(block) VMEM footprint.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_H = 256
BLOCK_W = 256

# 8-neighbourhood shifts for the local-max test.
_SHIFTS = ((-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1))


def _peak_kernel(img_ref, thresh_ref, count_ref, bg_ref):
    tile = img_ref[...]
    thresh = thresh_ref[0]
    # Local max over the 8-neighbourhood. Tile borders use wrapped
    # neighbours (jnp.roll); the L2 wrapper pads the full image with -inf
    # and the kernel additionally masks the tile rim so wrap artefacts
    # cannot create false peaks.
    is_max = tile > thresh
    for dy, dx in _SHIFTS:
        is_max &= tile >= jnp.roll(tile, (dy, dx), axis=(0, 1))
    h, w = tile.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    interior = (rows > 0) & (rows < h - 1) & (cols > 0) & (cols < w - 1)
    is_max &= interior
    count_ref[0, 0] = jnp.sum(is_max.astype(jnp.float32))
    # Background: mean of sub-threshold pixels (guard the empty case).
    below = tile <= thresh
    n_below = jnp.sum(below.astype(jnp.float32))
    bg_ref[0, 0] = jnp.where(
        n_below > 0, jnp.sum(jnp.where(below, tile, 0.0)) / jnp.maximum(n_below, 1.0), 0.0
    )


def peak_detect(img, thresh, *, bh: int = BLOCK_H, bw: int = BLOCK_W):
    """Per-tile Bragg peak counts and background over a detector image.

    Args:
      img: f32[H, W] detector still, H % bh == 0, W % bw == 0.
      thresh: f32[1] detection threshold.

    Returns:
      (counts, background): each f32[H/bh, W/bw].
    """
    h, w = img.shape
    assert h % bh == 0 and w % bw == 0, f"image {h}x{w} not aligned to {bh}x{bw}"
    grid = (h // bh, w // bw)
    out_shape = (
        jax.ShapeDtypeStruct(grid, jnp.float32),
        jax.ShapeDtypeStruct(grid, jnp.float32),
    )
    return pl.pallas_call(
        _peak_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(img, thresh)
