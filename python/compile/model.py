"""Layer-2 JAX compute graphs for the funcX payload functions.

Each graph wraps an L1 Pallas kernel with the padding / post-processing the
scientific function needs, and is what ``aot.py`` lowers to an HLO-text
artifact. The Rust workers execute these artifacts via PJRT; Python is
never on the request path.

Artifacts (shapes are fixed at AOT time — the Rust side owns batching):

  surrogate.hlo.txt   — AlphaFold-aaS stand-in: 2-layer MLP inference.
                        in:  x f32[128, 256] (batch of embeddings)
                        params: w1 f32[256, 512], b1 f32[512],
                                w2 f32[512, 128], b2 f32[128]
                        out: logits f32[128, 128]
  stills.hlo.txt      — SSX process_stills stand-in: Bragg-peak detection.
                        in:  img f32[512, 512], thresh f32[1]
                        out: counts f32[2, 2], background f32[2, 2],
                             total f32[] (summed peak count)
  reducer.hlo.txt     — MapReduce reducer stand-in: segment sum.
                        in:  ids i32[4096], vals f32[4096]
                        out: sums f32[256]
"""

import jax
import jax.numpy as jnp

from .kernels import mlp_block, peak_detect, segment_sum

# ---------------------------------------------------------------------------
# AOT-time shape contract, shared with aot.py and the Rust runtime
# (rust/src/runtime/artifacts.rs mirrors these constants).
# ---------------------------------------------------------------------------
SURROGATE_BATCH = 128
SURROGATE_D_IN = 256
SURROGATE_D_HID = 512
SURROGATE_D_OUT = 128

STILLS_H = 512
STILLS_W = 512
STILLS_BH = 256
STILLS_BW = 256

REDUCER_N = 4096
REDUCER_SEGMENTS = 256


def surrogate_infer(x, w1, b1, w2, b2):
    """MLP surrogate inference (AlphaFold-as-a-service §8). Both matmuls run
    the Pallas tiled kernel; XLA fuses the bias+GELU epilogue."""
    return (mlp_block(x, w1, b1, w2, b2),)


def stills_process(img, thresh):
    """SSX stills analysis (§2, Listing 1): tile-wise peak detection plus a
    detector-level total, background-corrected per tile."""
    counts, bg = peak_detect(img, thresh, bh=STILLS_BH, bw=STILLS_BW)
    total = jnp.sum(counts)
    return counts, bg, total


def reduce_shuffle(ids, vals):
    """MapReduce reduce-side aggregation (§7.3.1): keyed segment sum."""
    return (segment_sum(ids, vals, REDUCER_SEGMENTS),)


def surrogate_example_args():
    return (
        jax.ShapeDtypeStruct((SURROGATE_BATCH, SURROGATE_D_IN), jnp.float32),
        jax.ShapeDtypeStruct((SURROGATE_D_IN, SURROGATE_D_HID), jnp.float32),
        jax.ShapeDtypeStruct((SURROGATE_D_HID,), jnp.float32),
        jax.ShapeDtypeStruct((SURROGATE_D_HID, SURROGATE_D_OUT), jnp.float32),
        jax.ShapeDtypeStruct((SURROGATE_D_OUT,), jnp.float32),
    )


def stills_example_args():
    return (
        jax.ShapeDtypeStruct((STILLS_H, STILLS_W), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )


def reducer_example_args():
    return (
        jax.ShapeDtypeStruct((REDUCER_N,), jnp.int32),
        jax.ShapeDtypeStruct((REDUCER_N,), jnp.float32),
    )


ARTIFACTS = {
    "surrogate": (surrogate_infer, surrogate_example_args),
    "stills": (stills_process, stills_example_args),
    "reducer": (reduce_shuffle, reducer_example_args),
}
