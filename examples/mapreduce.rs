//! MapReduce WordCount on funcX (§7.3.1, Table 1) — real execution.
//!
//! Runs an actual (small) WordCount over a synthetic corpus through the
//! live stack, shuffling intermediate data through the two intra-endpoint
//! data planes the paper adopts (§5.2): the in-memory store and the
//! shared file system. Reports per-phase times for both, then prints the
//! paper-scale Table-1 model for comparison.
//!
//! ```text
//! cargo run --release --example mapreduce
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use funcx::data::{DataChannel, InMemoryChannel, SharedFsChannel, Transport};
use funcx::workloads::{mapreduce_phases, MapReduceSpec};

const MAPS: usize = 16;
const REDUCES: usize = 16;
const WORDS_PER_MAP: usize = 40_000;

const VOCAB: [&str; 24] = [
    "crystal", "beam", "detector", "protein", "structure", "x-ray", "photon", "energy",
    "sample", "diffraction", "lattice", "bragg", "peak", "synchrotron", "pixel", "image",
    "phase", "refine", "solve", "publish", "metadata", "transfer", "function", "endpoint",
];

fn synth_split(seed: u64) -> Vec<&'static str> {
    let mut rng = funcx::common::rng::Rng::new(seed);
    (0..WORDS_PER_MAP).map(|_| VOCAB[rng.below(VOCAB.len())]).collect()
}

/// Run the full WordCount through a data channel; returns phase times.
fn run_wordcount(channel: &dyn DataChannel) -> (f64, f64, f64, BTreeMap<String, u64>) {
    // Map phase: count words per split, partition by hash(word) % REDUCES,
    // write intermediate chunks to the channel.
    let t0 = Instant::now();
    for m in 0..MAPS {
        let words = synth_split(m as u64);
        let mut parts: Vec<BTreeMap<&str, u64>> = vec![BTreeMap::new(); REDUCES];
        for w in words {
            let r = w.bytes().fold(0usize, |h, b| (h * 31 + b as usize)) % REDUCES;
            *parts[r].entry(w).or_insert(0) += 1;
        }
        for (r, part) in parts.iter().enumerate() {
            let blob = part
                .iter()
                .map(|(w, c)| format!("{w} {c}"))
                .collect::<Vec<_>>()
                .join("\n");
            channel.put(&format!("shuffle/m{m}-r{r}"), blob.as_bytes()).unwrap();
        }
    }
    let map_s = t0.elapsed().as_secs_f64();

    // Shuffle-read + reduce phase.
    let t1 = Instant::now();
    let mut totals: BTreeMap<String, u64> = BTreeMap::new();
    for r in 0..REDUCES {
        for m in 0..MAPS {
            let blob = channel.get(&format!("shuffle/m{m}-r{r}")).unwrap();
            for line in std::str::from_utf8(&blob).unwrap().lines() {
                let (w, c) = line.split_once(' ').unwrap();
                *totals.entry(w.to_string()).or_insert(0) += c.parse::<u64>().unwrap();
            }
        }
    }
    let read_reduce_s = t1.elapsed().as_secs_f64();

    // Cleanup phase (intermediate deletion).
    let t2 = Instant::now();
    for r in 0..REDUCES {
        for m in 0..MAPS {
            channel.delete(&format!("shuffle/m{m}-r{r}")).unwrap();
        }
    }
    let cleanup_s = t2.elapsed().as_secs_f64();
    (map_s, read_reduce_s, cleanup_s, totals)
}

fn main() {
    println!(
        "WordCount: {MAPS} maps x {REDUCES} reduces, {} words, {} shuffle chunks",
        MAPS * WORDS_PER_MAP,
        MAPS * REDUCES
    );

    let mem = InMemoryChannel::default();
    let (map_m, red_m, clean_m, totals_mem) = run_wordcount(&mem);

    let fs = SharedFsChannel::temp().unwrap();
    let (map_f, red_f, clean_f, totals_fs) = run_wordcount(&fs);

    assert_eq!(totals_mem, totals_fs, "both data planes must agree");
    let grand: u64 = totals_mem.values().sum();
    assert_eq!(grand as usize, MAPS * WORDS_PER_MAP, "word conservation");

    println!("\nmeasured phase times (s)            in-memory   shared-fs");
    println!("  map + intermediate write        {map_m:>10.3}  {map_f:>10.3}");
    println!("  intermediate read + reduce      {red_m:>10.3}  {red_f:>10.3}");
    println!("  cleanup                         {clean_m:>10.3}  {clean_f:>10.3}");
    let top = totals_mem.iter().max_by_key(|(_, c)| **c).unwrap();
    println!("  top word: {:?} x{}", top.0, top.1);

    // Paper-scale projection (Table 1).
    println!("\nTable-1 model at paper scale (30 GB, 300x300):");
    for (app, spec) in [
        ("WordCount", MapReduceSpec::wordcount_paper()),
        ("Sort", MapReduceSpec::sort_paper()),
    ] {
        for t in [Transport::InMemoryStore, Transport::SharedFs] {
            let p = mapreduce_phases(&spec, t, 300);
            println!(
                "  {app:<10} {:<10} iw {:>6.2} s  ir {:>6.2} s  total {:>7.1} s",
                t.name(),
                p.intermediate_write_s,
                p.intermediate_read_s,
                p.total()
            );
        }
    }
    println!("\nmapreduce OK");
}
