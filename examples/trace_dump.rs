//! Flight-recorder walkthrough: a 3-task ref chain over a 2-shard
//! service plane, then dump each task's assembled timeline.
//!
//! Task A carries an oversized input, so the service offloads it to the
//! data fabric and dispatches a `DataRef`; its oversized result is
//! likewise stored by ref. B consumes A's result ref, C consumes B's —
//! the payload bytes never transit the service queues. Every hop
//! (submit, shard enqueue, forward, worker start/finish, ref resolve,
//! result store) lands in the flight recorder's per-component rings,
//! and `client.trace(task)` assembles one cross-component timeline per
//! task. The rendered output here is the worked example in
//! `docs/observability.md`.
//!
//! ```text
//! cargo run --release --example trace_dump
//! ```

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::Payload;
use funcx::datastore::{DataFabric, TieredConfig, TieredStore};
use funcx::endpoint::{link, EndpointBuilder};
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

fn main() {
    // 2 service shards: task state and endpoint queues hash across the
    // shard ring, so one chain's timeline spans shard components.
    let svc = Arc::new(FuncXService::new(ServiceConfig {
        service_shards: 2,
        max_payload_bytes: 4096, // force A's 64 KB input by-ref
        ..Default::default()
    }));
    let (_user, token) = svc.bootstrap_user("trace@demo");
    let fc = FuncXClient::new(svc.clone(), token);

    // One live endpoint with its own tiered store + fabric; results
    // over 4 KB are offloaded, so the chain links by DataRef.
    let ep = fc.register_endpoint("chain-ep", "").unwrap();
    let store = Arc::new(TieredStore::new(ep, TieredConfig::default()).unwrap());
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 1,
            workers_per_node: 2,
            max_result_bytes: 4096,
            ..Default::default()
        })
        .fabric(Arc::new(DataFabric::new(store)))
        .latency(svc.latency.clone())
        .clock(svc.clock.clone())
        .recorder(svc.recorder.clone())
        .heartbeat_period(0.05)
        .start(agent_side);
    let fh = svc.connect_endpoint(ep, fwd).unwrap();
    let echo = fc.register_function("echo", Payload::Echo).unwrap();

    // A -> B -> C: B and C are submitted by ref against the previous
    // task's result, so their inputs resolve through the data fabric.
    let payload = Value::Bytes(vec![0x5a; 64 * 1024]);
    let a = fc.run(echo, ep, &payload).unwrap();
    let ref_a = svc.wait_result_ref(a, Duration::from_secs(15)).unwrap();
    let b = fc.run_by_ref(echo, ep, &ref_a).unwrap();
    let ref_b = svc.wait_result_ref(b, Duration::from_secs(15)).unwrap();
    let c = fc.run_by_ref(echo, ep, &ref_b).unwrap();
    let out = fc.get_result(c, Duration::from_secs(15)).unwrap();
    assert_eq!(out, payload, "the chain must round-trip the payload");

    // Dump each task's assembled cross-component timeline.
    for (name, task) in [("A", a), ("B", b), ("C", c)] {
        let trace = fc.trace(task).expect("completed task must have a trace");
        println!("--- task {name} ---");
        print!("{}", trace.render());
        println!(
            "    ({} events across {} components)",
            trace.events.len(),
            trace.components().len()
        );
    }

    // The same plane, summarized: a few registry numbers for the chain.
    let snap = fc.metrics();
    println!(
        "registry: submitted={} completed={} ref_dispatched={} bytes_offloaded={}",
        snap.counter_total("funcx_tasks_submitted_total"),
        snap.counter_total("funcx_tasks_completed_total"),
        snap.counter_total("funcx_tasks_ref_dispatched_total"),
        snap.counter_total("funcx_bytes_offloaded_total"),
    );

    fh.shutdown();
    agent.join();
    println!("trace_dump OK");
}
