//! AlphaFold-as-a-Service (§8) — GPU-style inference serving on funcX.
//!
//! ALCF deployed AlphaFold behind funcX to provision accelerator nodes
//! on demand. This example reproduces the serving pattern with the
//! AOT-compiled surrogate model: an elastic endpoint scales from zero
//! when inference requests arrive, warm containers serve repeat
//! requests, and latency/throughput are reported per phase.
//!
//! ```text
//! make artifacts && cargo run --release --example alphafold_service
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::rng::Rng;
use funcx::common::task::Payload;
use funcx::containers::ContainerTech;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::metrics::summarize;
use funcx::runtime::PjrtRuntime;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

const REQUESTS: usize = 20;

fn main() {
    let art_dir = std::path::Path::new("artifacts");
    if !art_dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("alphafold@alcf.anl.gov");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("polaris-gpu", "ALCF inference endpoint").unwrap();

    // Elastic endpoint: scales from 0 nodes on demand (§6.3), with a
    // container image registered for the model environment (§4.2).
    let container = svc.registry.register_container("alphafold-env", ContainerTech::Singularity);
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig {
            min_nodes: 0,
            max_nodes: 2,
            workers_per_node: 2,
            strategy_period_s: 0.02,
            tasks_per_node_scaling: 4,
            ..Default::default()
        })
        .runtime(Arc::new(PjrtRuntime::load_dir(art_dir).unwrap()))
        // Realistic Table-3 Singularity start costs, scaled 100x down so
        // the example finishes quickly (same code path).
        .cold_start_scale(0.01)
        .heartbeat_period(0.1)
        .start(agent_side);
    let forwarder = svc.connect_endpoint(ep, fwd).unwrap();

    let infer = fc
        .register_function_with_container(
            "fold_sequence",
            Payload::Artifact("surrogate".into()),
            container,
        )
        .unwrap();

    // Model weights (the served checkpoint).
    let mut rng = Rng::new(11);
    let weights: Vec<Value> = vec![
        Value::F32s((0..256 * 512).map(|_| (rng.f64() as f32 - 0.5) * 0.03).collect()),
        Value::F32s(vec![0.01; 512]),
        Value::F32s((0..512 * 128).map(|_| (rng.f64() as f32 - 0.5) * 0.03).collect()),
        Value::F32s(vec![0.0; 128]),
    ];

    let mut latencies = Vec::new();
    let t0 = Instant::now();
    for i in 0..REQUESTS {
        // Each request embeds a "sequence" as a 128x256 feature block.
        let x: Vec<f32> = (0..128 * 256).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect();
        let input = Value::map([
            ("x", Value::F32s(x)),
            ("w1", weights[0].clone()),
            ("b1", weights[1].clone()),
            ("w2", weights[2].clone()),
            ("b2", weights[3].clone()),
        ]);
        let t = Instant::now();
        let task = fc.run(infer, ep, &input).unwrap();
        let out = fc.get_result(task, Duration::from_secs(120)).unwrap();
        let lat = t.elapsed().as_secs_f64();
        latencies.push(lat);
        let logits = match &out {
            Value::List(parts) => match &parts[0] {
                Value::F32s(v) => v.len(),
                _ => 0,
            },
            _ => 0,
        };
        assert_eq!(logits, 128 * 128);
        if i == 0 {
            println!("first request (incl. elastic scale-out + cold start): {lat:.3} s");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = summarize(&latencies[1..]); // skip the scale-out request
    println!(
        "served {REQUESTS} inferences in {wall:.2} s ({:.1} req/s)",
        REQUESTS as f64 / wall
    );
    println!(
        "warm latency (s): mean {:.3}  p50 {:.3}  p99 {:.3}  min {:.3}  max {:.3}",
        s.mean, s.p50, s.p99, s.min, s.max
    );
    println!(
        "nodes provisioned: {}, cold starts: {}, warm hits: {}",
        agent.stats.nodes_provisioned.load(std::sync::atomic::Ordering::Relaxed),
        agent.stats.cold_starts.load(std::sync::atomic::Ordering::Relaxed),
        agent.stats.warm_hits.load(std::sync::atomic::Ordering::Relaxed),
    );

    forwarder.shutdown();
    agent.join();
    println!("alphafold_service OK");
}
