//! SSX pipeline — the paper's §2 motivating workload, end to end.
//!
//! This is the repository's **end-to-end validation driver**: it proves
//! all layers compose on a real small workload —
//!
//! 1. synthetic serial-crystallography stills are "acquired" at the
//!    beamline and staged to the HPC endpoint via the Globus-like
//!    transfer service (§5.1),
//! 2. a live funcX stack (service → forwarder → agent → manager →
//!    worker) executes `process_stills` on each image, where the
//!    function body is the **AOT-compiled JAX/Pallas Bragg-peak kernel**
//!    run through PJRT (L1+L2+L3 composed; Python nowhere at runtime),
//! 3. per-image peak counts are aggregated and reported with the
//!    end-to-end latency breakdown (Fig. 3's stages).
//!
//! ```text
//! make artifacts && cargo run --release --example ssx_pipeline
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::rng::Rng;
use funcx::common::task::Payload;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::runtime::PjrtRuntime;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;
use funcx::transfer::{GlobusFile, TransferService, TransferStatus};

const H: usize = 512;
const W: usize = 512;
const N_IMAGES: usize = 24;

/// Synthesize a detector still with `n_peaks` planted Bragg peaks over
/// Poisson-ish background noise.
fn synth_still(rng: &mut Rng, n_peaks: usize) -> Vec<f32> {
    let mut img = vec![0f32; H * W];
    for px in img.iter_mut() {
        *px = (rng.f64() * 0.8) as f32; // background
    }
    for _ in 0..n_peaks {
        let y = 2 + rng.below(H - 4);
        let x = 2 + rng.below(W - 4);
        img[y * W + x] = 40.0 + (rng.f64() * 20.0) as f32;
    }
    img
}

fn main() {
    let art_dir = std::path::Path::new("artifacts");
    if !art_dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // --- stage data from the beamline to the compute facility (§5.1) ----
    let globus = TransferService::new();
    let beamline = globus.register_endpoint("aps#sector19", 1.25e9, 1.0);
    let hpc = globus.register_endpoint("alcf#theta-dtn", 1.25e9, 1.0);
    let image_bytes = (H * W * 4) as u64;
    let mut staged = Vec::new();
    for i in 0..N_IMAGES {
        let f = GlobusFile {
            endpoint: beamline,
            path: format!("/data/run42/still_{i:04}.h5"),
            size_bytes: image_bytes,
        };
        let tid = globus.submit(&f, hpc, &format!("/scratch/run42/still_{i:04}.h5"), 0.0).unwrap();
        staged.push(tid);
    }
    let stage_done = staged
        .iter()
        .map(|t| globus.completion_time(*t).unwrap())
        .fold(0.0f64, f64::max);
    for t in &staged {
        assert_eq!(globus.status(*t, stage_done).unwrap(), TransferStatus::Succeeded);
    }
    println!(
        "staged {N_IMAGES} stills ({:.1} MB) beamline->HPC in {:.2} s (simulated WAN)",
        N_IMAGES as f64 * image_bytes as f64 / 1e6,
        stage_done
    );

    // --- live funcX stack with the PJRT runtime attached ----------------
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_user, token) = svc.bootstrap_user("ssx@aps.anl.gov");
    let fc = FuncXClient::new(svc.clone(), token);
    let ep = fc.register_endpoint("theta", "ALCF Theta endpoint").unwrap();
    let runtime = Arc::new(PjrtRuntime::load_dir(art_dir).unwrap());
    let (fwd_side, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 2, workers_per_node: 2, ..Default::default() })
        .runtime(runtime)
        .latency(svc.latency.clone())
        .clock(svc.clock.clone())
        .heartbeat_period(0.1)
        .start(agent_side);
    let forwarder = svc.connect_endpoint(ep, fwd_side).unwrap();

    // --- register process_stills (Listing 1) = the Pallas stencil -------
    let process_stills =
        fc.register_function("process_stills", Payload::Artifact("stills".into())).unwrap();

    // --- run the pipeline ------------------------------------------------
    let mut rng = Rng::new(20260710);
    let mut expected: Vec<usize> = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..N_IMAGES {
        let n_peaks = 3 + rng.below(9);
        expected.push(n_peaks);
        let img = synth_still(&mut rng, n_peaks);
        inputs.push(Value::map([
            ("img", Value::F32s(img)),
            ("thresh", Value::F32s(vec![10.0])),
        ]));
    }
    // Images are ~1 MB each: a single 24-image batch would exceed the
    // service's 10 MB payload cap (§5.1) — exactly why funcX stages bulk
    // data out-of-band. Submit per-image (each under the cap).
    let t0 = Instant::now();
    let tasks: Vec<_> = inputs
        .iter()
        .map(|input| fc.run(process_stills, ep, input).unwrap())
        .collect();
    let results = fc.get_batch_results(&tasks, Duration::from_secs(120)).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    // --- validate + report -----------------------------------------------
    let mut total_peaks = 0.0;
    for (i, r) in results.iter().enumerate() {
        let parts = match r {
            Value::List(p) => p,
            _ => panic!("unexpected result shape"),
        };
        // outputs: counts[2,2], background[2,2], total
        let total = match &parts[2] {
            Value::F32s(v) => v[0],
            _ => panic!("bad total"),
        };
        assert_eq!(
            total as usize, expected[i],
            "image {i}: detected {total} peaks, planted {}",
            expected[i]
        );
        total_peaks += total;
    }
    println!(
        "processed {N_IMAGES} stills in {wall:.2} s ({:.1} images/s), {total_peaks} peaks found",
        N_IMAGES as f64 / wall
    );

    // Fig. 3-style latency breakdown for the batch.
    let b = svc.latency.stage_summaries();
    if b.completed > 0 {
        println!(
            "mean stage latency (ms): t_s {:.2}  t_f {:.2}  t_e {:.2}  t_w {:.2}",
            1e3 * b.t_s.mean,
            1e3 * b.t_f.mean,
            1e3 * b.t_e.mean,
            1e3 * b.t_w.mean
        );
    }

    forwarder.shutdown();
    agent.join();
    println!("ssx_pipeline OK");
}
