//! Colmena-style AI-steered campaign (§7.3.2, §8) on funcX.
//!
//! A *Thinker* steers a simulated molecular-design campaign: it keeps a
//! surrogate model (the AOT-compiled Pallas MLP, run via PJRT on the
//! workers) and iteratively (1) scores a candidate batch with the
//! surrogate, (2) "simulates" the top candidates (sleep-cost tasks),
//! (3) updates its acquisition state. Task inputs/results move through
//! the endpoint's in-memory data store, mirroring Colmena's Redis value
//! server (Table 2).
//!
//! ```text
//! make artifacts && cargo run --release --example colmena_campaign
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::rng::Rng;
use funcx::common::task::Payload;
use funcx::data::{DataChannel, InMemoryChannel};
use funcx::endpoint::{link, EndpointBuilder};
use funcx::runtime::PjrtRuntime;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

const ROUNDS: usize = 4;
const BATCH: usize = 128; // surrogate batch dimension (AOT contract)
const D_IN: usize = 256;
const TOP_K: usize = 8;

fn main() {
    let art_dir = std::path::Path::new("artifacts");
    if !art_dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Live stack with PJRT runtime + in-memory data store attached.
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("colmena@anl.gov");
    let fc = FuncXClient::new(svc.clone(), tok);
    let ep = fc.register_endpoint("theta", "campaign endpoint").unwrap();
    let store = Arc::new(InMemoryChannel::default());
    let (fwd, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 2, workers_per_node: 2, ..Default::default() })
        .runtime(Arc::new(PjrtRuntime::load_dir(art_dir).unwrap()))
        .data_channel(store.clone())
        .heartbeat_period(0.1)
        .start(agent_side);
    let forwarder = svc.connect_endpoint(ep, fwd).unwrap();

    let infer = fc.register_function("surrogate_infer", Payload::Artifact("surrogate".into())).unwrap();
    let simulate = fc.register_function("dft_simulate", Payload::Sleep(0.05)).unwrap();

    // Fixed surrogate weights for the campaign (the "trained model").
    let mut rng = Rng::new(7);
    let w1: Vec<f32> = (0..D_IN * 512).map(|_| (rng.f64() as f32 - 0.5) * 0.05).collect();
    let b1 = vec![0.0f32; 512];
    let w2: Vec<f32> = (0..512 * 128).map(|_| (rng.f64() as f32 - 0.5) * 0.05).collect();
    let b2 = vec![0.0f32; 128];

    let mut best_score = f32::NEG_INFINITY;
    let t0 = Instant::now();
    for round in 0..ROUNDS {
        // 1. Thinker generates a candidate batch (writes it to the value
        //    store, as Colmena's Thinker does; Table 2 "input write").
        let candidates: Vec<f32> =
            (0..BATCH * D_IN).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect();
        let key = format!("campaign/round{round}/candidates");
        let blob: Vec<u8> = candidates.iter().flat_map(|f| f.to_le_bytes()).collect();
        store.put(&key, &blob).unwrap();

        // 2. Surrogate inference on a worker via PJRT.
        let input = Value::map([
            ("x", Value::F32s(candidates)),
            ("w1", Value::F32s(w1.clone())),
            ("b1", Value::F32s(b1.clone())),
            ("w2", Value::F32s(w2.clone())),
            ("b2", Value::F32s(b2.clone())),
        ]);
        let t = fc.run(infer, ep, &input).unwrap();
        let out = fc.get_result(t, Duration::from_secs(60)).unwrap();
        let logits = match &out {
            Value::List(parts) => match &parts[0] {
                Value::F32s(v) => v.clone(),
                _ => panic!("bad logits"),
            },
            _ => panic!("bad result"),
        };
        // Acquisition score per candidate: mean logit.
        let scores: Vec<f32> = logits
            .chunks(128)
            .map(|row| row.iter().sum::<f32>() / 128.0)
            .collect();

        // 3. Pick top-K candidates and "simulate" them in parallel.
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|a, b| scores[*b].partial_cmp(&scores[*a]).unwrap());
        let sims: Vec<Value> = idx[..TOP_K].iter().map(|i| Value::Int(*i as i64)).collect();
        let tasks = fc.run_batch(simulate, ep, &sims).unwrap();
        fc.get_batch_results(&tasks, Duration::from_secs(60)).unwrap();
        let round_best = scores[idx[0]];
        best_score = best_score.max(round_best);
        println!(
            "round {round}: scored {BATCH} candidates, simulated top {TOP_K}, best {round_best:.4}"
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "campaign: {ROUNDS} rounds, {} tasks, {wall:.2} s, best acquisition {best_score:.4}",
        ROUNDS * (1 + TOP_K)
    );

    forwarder.shutdown();
    agent.join();
    println!("colmena_campaign OK");
}
