//! Flox-style federated learning over funcX endpoints (§8 "Distributed
//! ML" / Rural AI).
//!
//! Several *edge* endpoints train a shared linear model on local data;
//! a round consists of (1) broadcasting the global weights, (2) local
//! gradient computation on each endpoint, (3) aggregation of the
//! per-endpoint gradient sums through the AOT-compiled segment-sum
//! reducer on the aggregation endpoint. One edge endpoint's link is
//! severed mid-campaign to exercise the §4.1 fault-tolerance path
//! (queued tasks survive, the endpoint re-registers and resumes).
//!
//! ```text
//! make artifacts && cargo run --release --example federated_learning
//! ```

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::ids::EndpointId;
use funcx::common::rng::Rng;
use funcx::common::task::Payload;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::runtime::PjrtRuntime;
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

const EDGES: usize = 3;
const ROUNDS: usize = 6;
const DIM: usize = 16; // model dimension (packed into reducer segments)
const LOCAL_N: usize = 200;

/// True model the edges' data is generated from.
fn true_weights() -> Vec<f32> {
    (0..DIM).map(|i| (i as f32 * 0.37).sin()).collect()
}

fn main() {
    let art_dir = std::path::Path::new("artifacts");
    if !art_dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_u, tok) = svc.bootstrap_user("flox@uchicago.edu");
    let fc = FuncXClient::new(svc.clone(), tok);

    // Edge endpoints (Raspberry-Pi-class: 1 node, 1 worker) + an
    // aggregator with the PJRT runtime.
    let runtime = Arc::new(PjrtRuntime::load_dir(art_dir).unwrap());
    let mut edges: Vec<(EndpointId, _, _)> = Vec::new();
    for i in 0..EDGES {
        let ep = fc.register_endpoint(&format!("edge-{i}"), "rural sensor box").unwrap();
        let (fwd, agent_side) = link();
        let agent = EndpointBuilder::new()
            .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
            .heartbeat_period(0.05)
            .seed(100 + i as u64)
            .start(agent_side);
        let fh = svc.connect_endpoint(ep, fwd).unwrap();
        edges.push((ep, agent, fh));
    }
    let agg_ep = fc.register_endpoint("campus-agg", "aggregation server").unwrap();
    let (agg_fwd, agg_agent_side) = link();
    let agg_agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
        .runtime(runtime)
        .heartbeat_period(0.05)
        .start(agg_agent_side);
    let agg_fh = svc.connect_endpoint(agg_ep, agg_fwd).unwrap();

    // "Local training" = echo back a locally-computed gradient. The edge
    // function body computes grad of MSE for a linear model; we register
    // it as Echo and compute client-side gradients into the input, which
    // keeps the edge payload simple while still exercising the full
    // dispatch path per edge per round.
    let local_grad = fc.register_function("local_gradient", Payload::Echo).unwrap();
    let aggregate = fc.register_function("fedavg_reduce", Payload::Artifact("reducer".into())).unwrap();

    let w_star = true_weights();
    let mut global = vec![0f32; DIM];
    let mut rng = Rng::new(99);

    for round in 0..ROUNDS {
        // 1. Local gradient tasks on every edge endpoint.
        let mut grads: Vec<Vec<f32>> = Vec::new();
        let mut tasks = Vec::new();
        for (i, (ep, _, _)) in edges.iter().enumerate() {
            // Edge-local data: y = w*Tx + noise.
            let mut gsum = vec![0f32; DIM];
            for _ in 0..LOCAL_N {
                let x: Vec<f32> = (0..DIM).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect();
                let y: f32 = x.iter().zip(&w_star).map(|(a, b)| a * b).sum::<f32>()
                    + (rng.f64() as f32 - 0.5) * 0.01;
                let pred: f32 = x.iter().zip(&global).map(|(a, b)| a * b).sum();
                let err = pred - y;
                for d in 0..DIM {
                    gsum[d] += 2.0 * err * x[d] / LOCAL_N as f32;
                }
            }
            let input = Value::map([
                ("edge", Value::Int(i as i64)),
                ("grad", Value::F32s(gsum.clone())),
            ]);
            grads.push(gsum);
            tasks.push(fc.run(local_grad, *ep, &input).unwrap());
        }
        // Inject a failure in round 1: sever edge 0's link mid-round; the
        // forwarder requeues its in-flight work and we reconnect.
        if round == 1 {
            let (ep0, agent0, fh0) = edges.remove(0);
            fh0.shutdown();
            agent0.join();
            // Reconnect a fresh agent for the same endpoint id.
            let (fwd, agent_side) = link();
            let agent = EndpointBuilder::new()
                .config(EndpointConfig { min_nodes: 1, workers_per_node: 1, ..Default::default() })
                .heartbeat_period(0.05)
                .start(agent_side);
            let fh = svc.connect_endpoint(ep0, fwd).unwrap();
            edges.insert(0, (ep0, agent, fh));
            println!("round {round}: edge-0 agent lost and reconnected (tasks requeued)");
        }
        let edge_results = fc.get_batch_results(&tasks, Duration::from_secs(60)).unwrap();
        assert_eq!(edge_results.len(), EDGES);

        // 2. Aggregate gradients with the PJRT reducer: segment d sums
        //    grads[*][d] across edges.
        let mut ids = vec![0i32; 4096];
        let mut vals = vec![0f32; 4096];
        let mut k = 0;
        for g in &grads {
            for (d, v) in g.iter().enumerate() {
                ids[k] = d as i32;
                vals[k] = *v;
                k += 1;
            }
        }
        let input = Value::map([("ids", Value::I32s(ids)), ("vals", Value::F32s(vals))]);
        let t = fc.run(aggregate, agg_ep, &input).unwrap();
        let out = fc.get_result(t, Duration::from_secs(60)).unwrap();
        let sums = match &out {
            Value::List(parts) => match &parts[0] {
                Value::F32s(v) => v.clone(),
                _ => panic!("bad reducer output"),
            },
            _ => panic!("bad result"),
        };
        // 3. FedAvg step.
        let lr = 0.35;
        for d in 0..DIM {
            global[d] -= lr * sums[d] / EDGES as f32;
        }
        let dist: f32 = global
            .iter()
            .zip(&w_star)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        println!("round {round}: ||w - w*|| = {dist:.4}");
    }

    let final_dist: f32 = global
        .iter()
        .zip(&w_star)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt();
    assert!(final_dist < 0.8, "model must move toward w* (dist {final_dist})");

    for (_, agent, fh) in edges {
        fh.shutdown();
        agent.join();
    }
    agg_fh.shutdown();
    agg_agent.join();
    println!("federated_learning OK");
}
