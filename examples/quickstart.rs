//! Quickstart — the Listing-1 flow end to end on a live local stack.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Boots the cloud service, deploys a local endpoint (agent → manager →
//! workers), registers a function, runs it, and fetches the result —
//! exactly the `FuncXClient` flow from the paper's Listing 1.

use std::sync::Arc;
use std::time::Duration;

use funcx::common::config::{EndpointConfig, ServiceConfig};
use funcx::common::task::Payload;
use funcx::endpoint::{link, EndpointBuilder};
use funcx::sdk::FuncXClient;
use funcx::serialize::Value;
use funcx::service::FuncXService;

fn main() {
    // --- the cloud-hosted service + an authenticated client -------------
    let svc = Arc::new(FuncXService::new(ServiceConfig::default()));
    let (_user, token) = svc.bootstrap_user("you@example.org");
    let fc = FuncXClient::new(svc.clone(), token);

    // --- deploy an endpoint (the funcX agent) on "this laptop" ----------
    let endpoint_id = fc.register_endpoint("laptop", "my dev box").unwrap();
    let (forwarder_side, agent_side) = link();
    let agent = EndpointBuilder::new()
        .config(EndpointConfig { min_nodes: 1, workers_per_node: 4, ..Default::default() })
        .heartbeat_period(0.1)
        .start(agent_side);
    let forwarder = svc.connect_endpoint(endpoint_id, forwarder_side).unwrap();
    println!("endpoint {endpoint_id} online");

    // --- register + run a function (Listing 1) --------------------------
    let func_id = fc.register_function("process_stills", Payload::Echo).unwrap();
    let input_data = Value::map([
        ("inputs", Value::Str("image_0001.h5".into())),
        ("phil", Value::Str("params.phil".into())),
    ]);
    let task_id = fc.run(func_id, endpoint_id, &input_data).unwrap();
    let res = fc.get_result(task_id, Duration::from_secs(10)).unwrap();
    println!("result: {res:?}");
    assert_eq!(res, input_data);

    // --- batch submission (§4.6) ----------------------------------------
    let inputs: Vec<Value> = (0..32).map(Value::Int).collect();
    let tasks = fc.run_batch(func_id, endpoint_id, &inputs).unwrap();
    let results = fc.get_batch_results(&tasks, Duration::from_secs(30)).unwrap();
    assert_eq!(results, inputs);
    println!("batch of {} tasks OK", results.len());

    forwarder.shutdown();
    agent.join();
    println!("quickstart OK");
}
